//! Prediction-reserved continuous batching — the P-CB worker substrate.
//!
//! Where ILS admits against a conservative parallel cap and SCLS-CB
//! against the per-slice worst case (`cached + S`), this worker admits
//! against the request's **predicted** KV demand: a request is admitted
//! iff the KV it is *reserved* to grow to — `(input + allowed)·Δ`, where
//! `allowed` is its predicted remaining generation — fits alongside the
//! reservations of everything already running.
//!
//! Mispredict recovery keeps the no-OOM invariant unconditional:
//!
//! * **Under-prediction** — a request that exhausts its reservation
//!   without finishing is *evicted* at the iteration boundary: its KV is
//!   released and it goes back to the coordinator to be re-admitted with
//!   an enlarged reservation (paying a fresh prefill over input +
//!   generated, exactly like an SCLS-CB slice exit). Eviction fires
//!   *before* the reservation can be exceeded, so actual KV use never
//!   passes the projected sum, which admission keeps ≤ the budget.
//! * **Over-prediction** — a request that finishes with reservation to
//!   spare wasted that headroom for its whole residency; the unused tokens
//!   are reported per exit so the scheduler can account
//!   `wasted_kv_token_steps`.
//!
//! A lone-request clamp guarantees progress under tight budgets: when the
//! instance is idle and the front request's reservation alone exceeds the
//! budget, the reservation is clamped down to what fits (≥ 1 token), so
//! the request advances by eviction/re-admission cycles instead of
//! deadlocking — the invariant is never traded for liveness.

use std::collections::VecDeque;

use crate::core::Request;

use super::latency::EngineLatency;

/// A request in the running set, pinned with its admission-time
/// reservation.
#[derive(Debug)]
struct PredictedRunning {
    req: Request,
    /// Cached length (input + all generated tokens).
    cached: u32,
    /// Tokens still to generate (EOS oracle or the max-gen cap) — engine
    /// side only, never consulted for admission.
    remaining: u32,
    /// Reserved generation tokens for this residency (admission-time).
    allowed: u32,
    /// Tokens generated within this residency.
    gen_this_residency: u32,
    /// This entry's contribution to the projected-KV sum, fixed at
    /// admission: `(input_at_admission + allowed)·Δ`.
    reserved_kv: u64,
}

/// What `finish_iteration` hands back to the coordinator.
#[derive(Debug, Default)]
pub struct PredExits {
    /// Finished requests, each with its unused reservation (tokens the
    /// prediction over-shot by; 0 for exact or under-predictions).
    pub done: Vec<(Request, u32)>,
    /// Exhausted their reservation without finishing (under-predicted):
    /// KV released, must be re-admitted with a larger reservation.
    pub evicted: Vec<Request>,
}

/// One prediction-reserved continuous-batching LLM instance.
pub struct PredictiveContinuousWorker {
    pub waiting: VecDeque<Request>,
    running: Vec<PredictedRunning>,
    pub engine: EngineLatency,
    /// KV budget in bytes and per-token KV size.
    pub kv_budget: u64,
    pub kv_delta: u64,
    pub max_gen_len: u32,
    /// Running sum of `reserved_kv` over the running set (incremental so
    /// admission is O(1) per candidate even with deep queues).
    projected: u64,
}

impl PredictiveContinuousWorker {
    pub fn new(
        engine: EngineLatency,
        kv_budget: u64,
        kv_delta: u64,
        max_gen_len: u32,
    ) -> PredictiveContinuousWorker {
        PredictiveContinuousWorker {
            waiting: VecDeque::new(),
            running: Vec::new(),
            engine,
            kv_budget,
            kv_delta: kv_delta.max(1),
            max_gen_len,
            projected: 0,
        }
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    /// Projected KV: the sum of admission-time reservations of everything
    /// running. Actual KV use never exceeds this (eviction fires when a
    /// reservation is consumed), so admission against it is the no-OOM
    /// invariant.
    pub fn kv_projected(&self) -> u64 {
        self.projected
    }

    /// Reservation a request asks for: predicted remaining generation,
    /// clamped to at least 1 token and at most the distance to the
    /// generation cap. Falls back to the worst case when no prediction is
    /// stamped (plain conservative continuous batching).
    fn reservation(&self, req: &Request) -> u32 {
        let pred_total = req.predicted_gen.unwrap_or(self.max_gen_len);
        let to_cap = self.max_gen_len.saturating_sub(req.generated).max(1);
        pred_total.saturating_sub(req.generated).clamp(1, to_cap)
    }

    /// Begin the next iteration: admit whatever the predicted reservations
    /// say fits, then return the duration of one decode iteration over the
    /// running set (plus the prefill cost of requests admitted at this
    /// boundary). `None` = idle.
    pub fn begin_iteration(&mut self) -> Option<f64> {
        let mut admit_prefill = 0.0;
        while let Some(front) = self.waiting.front() {
            let mut allowed = self.reservation(front);
            let need = (front.input_len as u64 + allowed as u64) * self.kv_delta;
            if self.projected + need > self.kv_budget {
                if !self.running.is_empty() {
                    break;
                }
                // Lone-request clamp: shrink the reservation to what the
                // whole budget can hold so the instance makes progress.
                let fit = (self.kv_budget / self.kv_delta)
                    .saturating_sub(front.input_len as u64);
                if fit == 0 {
                    // Not even input + 1 token fits: this request can never
                    // be served on this instance, and it blocks the queue
                    // behind it for good (mirrors the ILS/SCLS-CB stall on
                    // oversized inputs, but say so instead of stalling
                    // silently).
                    log::warn!(
                        "request {} (input {} tokens) exceeds the KV budget \
                         ({} tokens) outright; instance queue is stalled",
                        front.id,
                        front.input_len,
                        self.kv_budget / self.kv_delta
                    );
                    break;
                }
                allowed = allowed.min(fit.min(u32::MAX as u64) as u32);
            }
            let mut req = self.waiting.pop_front().unwrap();
            req.slices += 1;
            admit_prefill += self.engine.prefill_mean(1, req.input_len);
            let remaining = self
                .max_gen_len
                .saturating_sub(req.generated)
                .min(req.remaining_to_eos())
                .max(1);
            let reserved_kv = (req.input_len as u64 + allowed as u64) * self.kv_delta;
            self.projected += reserved_kv;
            self.running.push(PredictedRunning {
                cached: req.input_len,
                remaining,
                allowed,
                gen_this_residency: 0,
                reserved_kv,
                req,
            });
        }
        if self.running.is_empty() {
            return None;
        }
        let n = self.running.len() as u32;
        let mean_l =
            (self.running.iter().map(|r| r.cached as u64).sum::<u64>() / n as u64) as u32;
        Some(admit_prefill + self.engine.decode_iter_mean(mean_l, n))
    }

    /// Crash-path surrender: hand back everything this instance holds —
    /// the running set (the caller re-prefills over input + generated) and
    /// the untouched waiting queue — and release every reservation (the
    /// projected-KV sum resets to zero with the running set).
    pub fn abandon(&mut self) -> (Vec<Request>, Vec<Request>) {
        self.projected = 0;
        (
            self.running.drain(..).map(|r| r.req).collect(),
            self.waiting.drain(..).collect(),
        )
    }

    /// Complete the iteration: every running request gains one token;
    /// finished requests exit as `done` (with their unused reservation),
    /// reservation-exhausted ones as `evicted` (with `input_len` advanced
    /// so re-admission prefills over the full context).
    pub fn finish_iteration(&mut self, now: f64) -> PredExits {
        for r in &mut self.running {
            r.cached += 1;
            r.remaining -= 1;
            r.gen_this_residency += 1;
            // First-token stamp for TTFT accounting: this boundary delivers
            // the request's first generated token. (Evicted requests resume
            // with `generated > 0` and keep their original stamp.)
            if r.req.generated == 0 && r.req.first_token_at.is_none() {
                r.req.first_token_at = Some(now);
            }
            r.req.generated += 1;
        }
        let mut out = PredExits::default();
        let mut k = 0;
        while k < self.running.len() {
            if self.running[k].remaining == 0 {
                let fin = self.running.swap_remove(k);
                self.projected -= fin.reserved_kv;
                let unused = fin.allowed.saturating_sub(fin.gen_this_residency);
                let mut req = fin.req;
                req.finished_at = Some(now);
                out.done.push((req, unused));
            } else if self.running[k].gen_this_residency >= self.running[k].allowed {
                let evicted = self.running.swap_remove(k);
                self.projected -= evicted.reserved_kv;
                let mut req = evicted.req;
                // Re-admission prefills over everything generated so far
                // (the KV cache is dropped on eviction).
                req.input_len = evicted.cached;
                out.evicted.push(req);
            } else {
                k += 1;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const DELTA: u64 = 800 * 1024;

    fn worker(budget_tokens: u64) -> PredictiveContinuousWorker {
        let mut lat = EngineLatency::ds(1);
        lat.jitter = 0.0;
        PredictiveContinuousWorker::new(lat, budget_tokens * DELTA, DELTA, 1024)
    }

    fn req(id: u64, input: u32, gen: u32, pred: u32) -> Request {
        let mut r = Request::new(id, 0.0, input, gen);
        r.predicted_gen = Some(pred);
        r
    }

    #[test]
    fn admission_reserves_predicted_not_worst_case() {
        // Budget: 400 tokens. Worst-case (cap 1024) admission would admit
        // nothing; predicted admission fits two (100 + 80)-token requests.
        let mut w = worker(400);
        w.waiting.push_back(req(0, 100, 500, 80));
        w.waiting.push_back(req(1, 100, 500, 80));
        w.waiting.push_back(req(2, 100, 500, 80));
        w.begin_iteration().unwrap();
        assert_eq!(w.running_len(), 2, "third reservation must not fit");
        assert_eq!(w.kv_projected(), 2 * 180 * DELTA);
    }

    #[test]
    fn oracle_prediction_never_evicts() {
        let mut w = worker(10_000);
        w.waiting.push_back(req(0, 10, 5, 5));
        w.begin_iteration().unwrap();
        for t in 0..5 {
            let out = w.finish_iteration(t as f64);
            assert!(out.evicted.is_empty());
            if t < 4 {
                w.begin_iteration().unwrap();
            } else {
                let (done, unused) = out.done.into_iter().next().expect("finished at EOS");
                assert_eq!(done.generated, 5);
                assert_eq!(unused, 0, "exact prediction wastes nothing");
            }
        }
        assert_eq!(w.running_len(), 0);
        assert_eq!(w.kv_projected(), 0);
    }

    #[test]
    fn underprediction_evicts_with_context_advanced() {
        // Predicted 4, actually needs 20: evicted after 4 tokens.
        let mut w = worker(10_000);
        w.waiting.push_back(req(0, 10, 20, 4));
        w.begin_iteration().unwrap();
        let mut evicted = None;
        for t in 0..4 {
            let out = w.finish_iteration(t as f64);
            assert!(out.done.is_empty());
            if !out.evicted.is_empty() {
                evicted = Some(out.evicted.into_iter().next().unwrap());
                break;
            }
            w.begin_iteration().unwrap();
        }
        let r = evicted.expect("reservation exhaustion must evict");
        assert_eq!(r.generated, 4);
        assert_eq!(r.input_len, 14, "re-admission prefills input+generated");
        assert_eq!(w.running_len(), 0, "KV released at eviction");
        assert_eq!(w.kv_projected(), 0);
    }

    #[test]
    fn overprediction_reports_unused_reservation() {
        // Predicted 100, actually needs 3: 97 reserved tokens wasted.
        let mut w = worker(10_000);
        w.waiting.push_back(req(0, 10, 3, 100));
        w.begin_iteration().unwrap();
        w.finish_iteration(1.0);
        w.begin_iteration().unwrap();
        w.finish_iteration(2.0);
        w.begin_iteration().unwrap();
        let out = w.finish_iteration(3.0);
        let (done, unused) = out.done.into_iter().next().unwrap();
        assert_eq!(done.generated, 3);
        assert_eq!(unused, 97);
    }

    #[test]
    fn lone_request_clamp_keeps_progress_and_invariant() {
        // Budget 120 tokens; request wants input 100 + predicted 500.
        let mut w = worker(120);
        w.waiting.push_back(req(0, 100, 500, 500));
        w.begin_iteration().unwrap();
        assert_eq!(w.running_len(), 1, "idle instance must clamp and admit");
        assert!(w.kv_projected() <= w.kv_budget, "invariant holds post-clamp");
        // The clamped reservation is 20 tokens; eviction fires there.
        let mut evicted = false;
        for t in 0..20 {
            let out = w.finish_iteration(t as f64);
            if !out.evicted.is_empty() {
                assert_eq!(out.evicted[0].generated, 20);
                evicted = true;
                break;
            }
            w.begin_iteration().unwrap();
        }
        assert!(evicted);
    }

    #[test]
    fn ttft_stamped_at_first_decode_iteration() {
        let mut w = worker(10_000);
        w.waiting.push_back(req(0, 10, 5, 5));
        let mut now = 0.0;
        let done = loop {
            let d = w.begin_iteration().unwrap();
            now += d;
            let out = w.finish_iteration(now);
            if let Some((r, _)) = out.done.into_iter().next() {
                break r;
            }
        };
        let first = done.first_token_at.expect("first token stamped");
        assert!(
            first < done.finished_at.unwrap(),
            "TTFT must be strictly earlier than finish"
        );
    }

    #[test]
    fn abandon_surrenders_state_and_releases_reservations() {
        let mut w = worker(200);
        w.waiting.push_back(req(0, 100, 500, 80)); // reserves 180 tokens
        w.waiting.push_back(req(1, 100, 500, 80)); // does not fit: waits
        w.begin_iteration().unwrap();
        w.finish_iteration(1.0);
        let (running, waiting) = w.abandon();
        assert_eq!(running.len(), 1);
        assert_eq!(running[0].id, 0);
        assert_eq!(running[0].generated, 1, "boundary state survives");
        assert_eq!(waiting.len(), 1);
        assert_eq!(waiting[0].id, 1);
        assert_eq!(w.running_len(), 0);
        assert_eq!(w.kv_projected(), 0, "reservations fully released");
        assert!(w.begin_iteration().is_none(), "instance is empty");
    }

    #[test]
    fn missing_prediction_falls_back_to_worst_case() {
        let mut w = worker(4096);
        let r = Request::new(0, 0.0, 64, 2000); // no predicted_gen stamped
        w.waiting.push_back(r);
        w.begin_iteration().unwrap();
        // Reservation = cap (1024) since generated = 0.
        assert_eq!(w.kv_projected(), (64 + 1024) * DELTA);
    }

    #[test]
    fn projection_constant_over_residency() {
        let mut w = worker(10_000);
        w.waiting.push_back(req(0, 100, 1000, 50));
        w.begin_iteration().unwrap();
        let p0 = w.kv_projected();
        w.finish_iteration(1.0);
        w.begin_iteration().unwrap();
        assert_eq!(w.kv_projected(), p0, "reservation is fixed at admission");
    }
}
