//! PJRT runtime: load the AOT HLO-text artifacts and execute them from the
//! Rust hot path. Python never runs here — `make artifacts` is the only
//! compile-path step (see python/compile/aot.py and DESIGN.md).

pub mod artifacts;
pub mod client;

pub use artifacts::{Bucket, Manifest};
pub use client::{ModelRuntime, SliceResult};
