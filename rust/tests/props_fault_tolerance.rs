//! Property suite for the elastic fault-tolerant fleet (join / drain /
//! crash with slice-boundary migration and stale-work reclaim).
//!
//! Two families of guarantees:
//!
//! 1. **Fault-free identity** — running any policy through the faulted
//!    loop with [`FaultPlan::none`] is *byte-identical* (on the
//!    `RunMetrics::to_json` event log) to the unfaulted loop, and — for
//!    the policies with frozen pre-trait drivers — to `sim::reference`.
//!    The elastic-fleet machinery must be invisible until a plan says
//!    otherwise.
//!
//! 2. **No lost work** — under randomized traces and randomized fault
//!    plans that keep worker 0 untouched (so at least one worker is
//!    always alive), every request completes exactly once with its full
//!    generation length: a crash loses at most the in-flight slice, never
//!    a request. Counter identities ride along: `reclaimed_requests ≥
//!    lost_slices`, and crash-free plans keep every crash counter at 0.

use std::collections::HashMap;

use scls::engine::presets::{EngineKind, EnginePreset};
use scls::estimator::TransferCost;
use scls::sim::driver::{SimConfig, Simulation};
use scls::sim::reference::{run_ils_reference, run_scls_cb_reference, run_sliced_reference};
use scls::sim::FaultPlan;
use scls::scheduler::spec::SchedulerSpec;
use scls::testprop::{check, Gen};
use scls::workload::distributions::WorkloadKind;
use scls::workload::{Trace, TraceConfig};
use scls::{prop_assert, prop_assert_eq};

fn trace(kind: WorkloadKind, rate: f64, duration: f64, seed: u64) -> Trace {
    Trace::generate(&TraceConfig {
        kind,
        rate,
        duration,
        max_input_len: 1024,
        max_gen_len: 1024,
        seed,
    })
}

fn cfg(workers: usize, kind: EngineKind, seed: u64) -> SimConfig {
    SimConfig::new(workers, EnginePreset::paper(kind), 1024, seed)
}

/// The byte-level fingerprint two runs must share to count as identical.
fn fingerprint(m: &scls::metrics::RunMetrics) -> String {
    m.to_json().to_string_pretty()
}

/// Policies with fault hooks wired (the other registry names keep the
/// default no-op hooks and are covered by the identity tests only).
const ELASTIC: [&str; 5] = ["scls", "ils", "p-scls", "scls-cb", "p-cb"];

/// Every completed request appears exactly once with its full generation
/// length (target capped by the run's max-gen limit).
fn assert_complete(
    m: &scls::metrics::RunMetrics,
    t: &Trace,
    label: &str,
) -> scls::testprop::PropResult {
    prop_assert_eq!(
        m.completed.len(),
        t.len(),
        "{label}: {} of {} requests completed",
        m.completed.len(),
        t.len()
    );
    let mut seen: HashMap<u64, u32> = HashMap::new();
    for c in &m.completed {
        prop_assert!(
            seen.insert(c.id, c.generated).is_none(),
            "{label}: request {} completed twice",
            c.id
        );
    }
    for r in &t.requests {
        let want = r.target_gen_len.min(1024).max(1);
        let got = seen.get(&r.id).copied();
        prop_assert_eq!(
            got,
            Some(want),
            "{label}: request {} generated {:?}, wanted {}",
            r.id,
            got,
            want
        );
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// 1. Fault-free identity
// ---------------------------------------------------------------------------

#[test]
fn none_plan_is_byte_identical_for_every_policy() {
    let names = [
        "sls", "so", "pm", "ab", "lb", "scls", "ils", "scls-cb", "p-scls", "p-cb", "d-scls",
        "p-srpt", "sw-slo",
    ];
    for kind in [EngineKind::Hf, EngineKind::Ds] {
        let t = trace(WorkloadKind::CodeFuse, 5.0, 30.0, 601);
        let c = cfg(4, kind, 601);
        let sim = Simulation::new(c);
        for name in names {
            let plain = sim.run_named(&t, name, 128).unwrap();
            let faulted = sim.run_named_faulted(&t, name, 128, &FaultPlan::none()).unwrap();
            assert_eq!(
                fingerprint(&plain),
                fingerprint(&faulted),
                "{name} on {} diverged under the empty fault plan",
                kind.name()
            );
        }
    }
}

#[test]
fn none_plan_matches_frozen_references() {
    let preset = EnginePreset::paper(EngineKind::Ds);
    let t = trace(WorkloadKind::CodeFuse, 6.0, 35.0, 602);
    let c = cfg(4, EngineKind::Ds, 602);
    let sim = Simulation::new(c.clone());
    let none = FaultPlan::none();
    assert_eq!(
        fingerprint(&run_sliced_reference(&t, &SchedulerSpec::scls(&preset, 128), &c)),
        fingerprint(&sim.run_named_faulted(&t, "scls", 128, &none).unwrap()),
        "SCLS faulted-loop diverged from the pre-trait driver"
    );
    assert_eq!(
        fingerprint(&run_ils_reference(&t, &c)),
        fingerprint(&sim.run_named_faulted(&t, "ils", 128, &none).unwrap()),
        "ILS faulted-loop diverged from the pre-trait driver"
    );
    assert_eq!(
        fingerprint(&run_scls_cb_reference(&t, &c, 128)),
        fingerprint(&sim.run_named_faulted(&t, "scls-cb", 128, &none).unwrap()),
        "SCLS-CB faulted-loop diverged from the pre-trait driver"
    );
}

// ---------------------------------------------------------------------------
// 2. No lost work under randomized fault plans
// ---------------------------------------------------------------------------

/// A random plan over `workers` initial workers that never touches worker
/// 0, so the accepting fleet is never empty. Returns the plan and how many
/// crash events it contains.
fn random_plan(g: &mut Gen, workers: usize, horizon: f64) -> (FaultPlan, usize) {
    let mut plan = FaultPlan::none();
    let mut crashes = 0;
    for _ in 0..g.usize(1, 4) {
        let at = g.f64(1.0, horizon);
        match g.usize(0, 2) {
            0 => {
                plan = plan.crash(g.usize(1, workers - 1), at);
                crashes += 1;
            }
            1 => plan = plan.drain(g.usize(1, workers - 1), at),
            _ => plan = plan.join(g.u32(1, 2), at),
        }
    }
    (plan, crashes)
}

#[test]
fn randomized_faults_lose_no_requests() {
    check("fault-no-lost-work", 10, |g: &mut Gen| {
        let kind = if g.bool() { EngineKind::Hf } else { EngineKind::Ds };
        let workload = if g.bool() {
            WorkloadKind::CodeFuse
        } else {
            WorkloadKind::ShareGpt
        };
        let rate = *g.pick(&[3.0, 6.0]);
        let workers = *g.pick(&[2usize, 3, 5]);
        let seed = g.u64();
        let t = trace(workload, rate, 25.0, seed);
        let (plan, crashes) = random_plan(g, workers, 40.0);
        let sim = Simulation::new(cfg(workers, kind, seed));
        for name in ELASTIC {
            let m = sim.run_named_faulted(&t, name, 128, &plan).unwrap();
            let label = format!("{name} ({workers}w seed {seed} plan {plan:?})");
            assert_complete(&m, &t, &label)?;
            prop_assert!(
                m.reclaimed_requests >= m.lost_slices,
                "{label}: reclaimed {} < lost slices {}",
                m.reclaimed_requests,
                m.lost_slices
            );
            prop_assert!(
                m.worker_crashes as usize <= crashes,
                "{label}: {} crashes recorded, {} scheduled",
                m.worker_crashes,
                crashes
            );
            if crashes == 0 {
                prop_assert_eq!(m.worker_crashes, 0, "{label}: phantom crash");
                prop_assert_eq!(m.lost_slices, 0, "{label}: lost slices without a crash");
            }
        }
        Ok(())
    });
}

#[test]
fn drain_only_plans_migrate_without_loss() {
    // Stagger a drain of every worker but 0, with replacements joining
    // later: graceful handoff must never count a crash or lose a slice.
    for workers in [2usize, 4] {
        let t = trace(WorkloadKind::CodeFuse, 5.0, 30.0, 611);
        let mut plan = FaultPlan::none();
        for w in 1..workers {
            plan = plan.drain(w, 5.0 * w as f64);
        }
        plan = plan.join(workers as u32 - 1, 20.0);
        let sim = Simulation::new(cfg(workers, EngineKind::Ds, 611));
        for name in ELASTIC {
            let m = sim.run_named_faulted(&t, name, 128, &plan).unwrap();
            assert_eq!(m.completed.len(), t.len(), "{name} lost requests on drain");
            assert_eq!(m.worker_crashes, 0, "{name} counted a crash on drain");
            assert_eq!(m.lost_slices, 0, "{name} lost a slice on drain");
        }
    }
}

#[test]
fn rolling_restart_completes_everything() {
    let workers = 4usize;
    let t = trace(WorkloadKind::CodeFuse, 5.0, 30.0, 612);
    let plan = FaultPlan::rolling(workers, 6.0);
    let sim = Simulation::new(cfg(workers, EngineKind::Ds, 612));
    for name in ELASTIC {
        let m = sim.run_named_faulted(&t, name, 128, &plan).unwrap();
        assert_eq!(m.completed.len(), t.len(), "{name} lost requests in rolling restart");
        assert_eq!(m.worker_crashes, 0, "{name}: rolling restarts are graceful");
        assert_eq!(m.lost_slices, 0, "{name}: rolling restarts lose nothing");
    }
}

#[test]
fn crash_reclaims_and_recompletes() {
    // A mid-run crash of a loaded worker: survivors resume at the last
    // slice boundary and everything still completes exactly once.
    let workers = 3usize;
    let t = trace(WorkloadKind::CodeFuse, 8.0, 25.0, 613);
    let plan = FaultPlan::none().crash(1, 6.0).crash(2, 12.0).join(2, 15.0);
    let sim = Simulation::new(cfg(workers, EngineKind::Ds, 613));
    for name in ELASTIC {
        let m = sim.run_named_faulted(&t, name, 128, &plan).unwrap();
        assert_eq!(m.completed.len(), t.len(), "{name} lost requests on crash");
        assert_eq!(m.worker_crashes, 2, "{name} miscounted crashes");
        assert!(
            m.reclaimed_requests >= m.lost_slices,
            "{name}: reclaimed {} < lost slices {}",
            m.reclaimed_requests,
            m.lost_slices
        );
    }
}

#[test]
fn join_only_plans_touch_no_fault_counters() {
    let t = trace(WorkloadKind::CodeFuse, 6.0, 25.0, 614);
    let plan = FaultPlan::none().join(2, 8.0);
    let sim = Simulation::new(cfg(2, EngineKind::Ds, 614));
    for name in ELASTIC {
        let m = sim.run_named_faulted(&t, name, 128, &plan).unwrap();
        assert_eq!(m.completed.len(), t.len(), "{name} lost requests on join");
        assert_eq!(m.worker_crashes, 0);
        assert_eq!(m.reclaimed_requests, 0);
        assert_eq!(m.lost_slices, 0);
        assert_eq!(m.migrations, 0);
    }
}

// ---------------------------------------------------------------------------
// 3. Coordinator crash and ledger reconstruction
// ---------------------------------------------------------------------------

/// The completion set as a canonical `(id, generated)` list — the unit of
/// comparison for the reconstruction differential. The coordinator rebuild
/// loses soft state (round-robin cursor, deficit quanta), so runs are not
/// byte-identical; the guarantee is that the *set* of completed work is.
fn completion_set(m: &scls::metrics::RunMetrics) -> Vec<(u64, u32)> {
    let mut v: Vec<(u64, u32)> = m.completed.iter().map(|c| (c.id, c.generated)).collect();
    v.sort_unstable();
    v
}

#[test]
fn coordinator_crash_reconstruction_differential() {
    // Drop the coordinator mid-run, alone and amid worker churn. The
    // successor rebuilds its ledger from worker reports; every policy
    // (including the worker-locus ones, for which recovery is a no-op)
    // must finish the exact same completion set as the fault-free run,
    // with the crash observed exactly once.
    let t = trace(WorkloadKind::CodeFuse, 7.0, 25.0, 620);
    let sim = Simulation::new(cfg(3, EngineKind::Ds, 620));
    let solo = FaultPlan::none().coordinator_crash(9.0);
    let churn = FaultPlan::none().crash(1, 6.0).coordinator_crash(10.0).join(1, 14.0);
    for name in ELASTIC {
        let base = sim.run_named(&t, name, 128).unwrap();
        let m = sim.run_named_faulted(&t, name, 128, &solo).unwrap();
        assert_eq!(
            completion_set(&m),
            completion_set(&base),
            "{name}: coordinator crash changed the completion set"
        );
        assert_eq!(m.coordinator_crashes, 1, "{name} miscounted the crash");
        // A coordinator crash alone touches no worker: no reclaim, no
        // slice loss, no migration.
        assert_eq!(m.worker_crashes, 0, "{name}");
        assert_eq!(m.lost_slices, 0, "{name} lost a slice without a worker fault");
        assert_eq!(m.migrations, 0, "{name} migrated without a worker fault");

        let m = sim.run_named_faulted(&t, name, 128, &churn).unwrap();
        assert_eq!(
            completion_set(&m),
            completion_set(&base),
            "{name}: crash + rebuild lost or duplicated requests"
        );
        assert_eq!(m.coordinator_crashes, 1, "{name}");
        assert_eq!(m.worker_crashes, 1, "{name}");
        assert!(
            m.reclaimed_requests >= m.lost_slices,
            "{name}: reclaimed {} < lost slices {}",
            m.reclaimed_requests,
            m.lost_slices
        );
    }
}

#[test]
fn randomized_coordinator_crashes_lose_no_requests() {
    check("coord-crash-no-lost-work", 10, |g: &mut Gen| {
        let workers = *g.pick(&[2usize, 4]);
        let seed = g.u64();
        let t = trace(WorkloadKind::CodeFuse, 6.0, 20.0, seed);
        let (mut plan, _) = random_plan(g, workers, 30.0);
        let n_coord = g.usize(1, 3);
        for _ in 0..n_coord {
            plan = plan.coordinator_crash(g.f64(1.0, 30.0));
        }
        let sim = Simulation::new(cfg(workers, EngineKind::Ds, seed));
        for name in ELASTIC {
            let m = sim.run_named_faulted(&t, name, 128, &plan).unwrap();
            let label = format!("{name} ({workers}w seed {seed} plan {plan:?})");
            assert_complete(&m, &t, &label)?;
            // Events past the drain-out of the run never fire, so the
            // observed count is bounded, not exact.
            prop_assert!(
                m.coordinator_crashes as usize <= n_coord,
                "{label}: {} coordinator crashes recorded, {} scheduled",
                m.coordinator_crashes,
                n_coord
            );
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------------
// 4. Probabilistic fault plans (mtbf / burst grammar)
// ---------------------------------------------------------------------------

#[test]
fn stochastic_plan_expansion_is_byte_stable() {
    // The same seeded spec expands to the identical event schedule every
    // time, and a run driven by it replays byte-identically.
    let spec = "mtbf:8,mttr:2,seed:7";
    let a = FaultPlan::parse_with_horizon(spec, 4, 40.0).unwrap();
    let b = FaultPlan::parse_with_horizon(spec, 4, 40.0).unwrap();
    assert_eq!(format!("{a:?}"), format!("{b:?}"), "expansion must be deterministic");
    let c = FaultPlan::parse_with_horizon("mtbf:8,mttr:2,seed:8", 4, 40.0).unwrap();
    assert_ne!(
        format!("{a:?}"),
        format!("{c:?}"),
        "different seeds must draw different schedules"
    );

    let t = trace(WorkloadKind::CodeFuse, 5.0, 30.0, 630);
    let sim = Simulation::new(cfg(4, EngineKind::Ds, 630));
    for name in ELASTIC {
        let x = sim.run_named_faulted(&t, name, 128, &a).unwrap();
        let y = sim.run_named_faulted(&t, name, 128, &b).unwrap();
        assert_eq!(
            fingerprint(&x),
            fingerprint(&y),
            "{name}: seeded mtbf plan did not replay byte-identically"
        );
        assert_complete(&x, &t, &format!("{name} mtbf")).unwrap();
    }
}

#[test]
fn burst_plans_crash_and_recover_without_loss() {
    // A correlated burst: K simultaneous crashes drawn at a seeded rate,
    // each followed by a recovery join. Worker 0 is always spared, so the
    // run drains and everything completes.
    let plan = FaultPlan::parse_with_horizon("burst:2@0.2,mttr:3,seed:11", 4, 30.0).unwrap();
    let t = trace(WorkloadKind::CodeFuse, 5.0, 25.0, 631);
    let sim = Simulation::new(cfg(4, EngineKind::Ds, 631));
    for name in ELASTIC {
        let m = sim.run_named_faulted(&t, name, 128, &plan).unwrap();
        assert_eq!(m.completed.len(), t.len(), "{name} lost requests under burst plan");
        assert!(
            m.reclaimed_requests >= m.lost_slices,
            "{name}: reclaimed {} < lost slices {}",
            m.reclaimed_requests,
            m.lost_slices
        );
    }
}

// ---------------------------------------------------------------------------
// 5. KV-transfer cost on migration
// ---------------------------------------------------------------------------

fn kv_cfg(workers: usize, seed: u64) -> SimConfig {
    cfg(workers, EngineKind::Ds, seed)
        .with_kv_transfer(Some(TransferCost::from_bandwidth(1_000_000.0)))
}

#[test]
fn kv_pricing_is_invisible_without_migrations() {
    // With the transfer model enabled but no faults, nothing migrates and
    // the run is byte-identical to the unpriced one.
    let t = trace(WorkloadKind::CodeFuse, 5.0, 25.0, 640);
    let plain = Simulation::new(cfg(3, EngineKind::Ds, 640));
    let priced = Simulation::new(kv_cfg(3, 640));
    for name in ELASTIC {
        let a = plain.run_named(&t, name, 128).unwrap();
        let b = priced.run_named(&t, name, 128).unwrap();
        assert_eq!(
            fingerprint(&a),
            fingerprint(&b),
            "{name}: kv pricing perturbed a migration-free run"
        );
        assert_eq!(b.kv_tokens_migrated, 0);
        assert_eq!(b.migration_stall_s, 0.0);
    }
}

#[test]
fn migrations_always_move_kv_tokens_when_priced() {
    // Drain one of two loaded workers: queued work must migrate, and with
    // the transfer model on, every migration carries tokens and a stall.
    let t = trace(WorkloadKind::CodeFuse, 8.0, 25.0, 641);
    let plan = FaultPlan::none().drain(1, 5.0).join(1, 15.0);
    let sim = Simulation::new(kv_cfg(2, 641));
    let mut total_migrations = 0u64;
    for name in ELASTIC {
        let m = sim.run_named_faulted(&t, name, 128, &plan).unwrap();
        assert_eq!(m.completed.len(), t.len(), "{name} lost requests on priced drain");
        if m.migrations > 0 {
            assert!(
                m.kv_tokens_migrated > 0,
                "{name}: {} migrations moved zero KV tokens",
                m.migrations
            );
            assert!(
                m.migration_stall_s > 0.0,
                "{name}: priced migrations must stall"
            );
        } else {
            assert_eq!(m.kv_tokens_migrated, 0, "{name}: phantom KV traffic");
        }
        total_migrations += m.migrations;
    }
    assert!(
        total_migrations > 0,
        "draining half a loaded fleet must migrate something somewhere"
    );
}
