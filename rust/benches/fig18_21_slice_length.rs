//! Figs. 18–21 — the slice-length sweep: throughput/response time (18),
//! dive-in counters (19), reschedule distribution + early-return ratio
//! (20) and load imbalance (21) as S goes from 32 to 512. Prints the
//! reproduced sweep for both engines, then times the extremes (S controls
//! how many reschedules the DES must simulate).

use scls::bench::figures::{fig18_21, run_cell, FigureConfig};
use scls::bench::harness::{bench, report_header};
use scls::engine::presets::EngineKind;

fn main() {
    let fc = FigureConfig::quick(0.1);
    fig18_21(&fc, EngineKind::Ds, &[32, 64, 128, 256, 512]).print();
    fig18_21(&fc, EngineKind::Hf, &[32, 64, 128, 256, 512]).print();

    println!("{}", report_header());
    let small = FigureConfig::quick(0.05);
    for s_len in [32u32, 128, 512] {
        let r = bench(&format!("SCLS DS @ S={s_len} (30 s trace)"), || {
            run_cell(&small, EngineKind::Ds, "SCLS", 20.0, s_len)
        });
        println!("{}", r.report());
    }
}
