//! Comment/string-stripping token scanner for the lint pass.
//!
//! Not a Rust parser: a single forward scan that is exact about the three
//! things the rules need — (1) which characters are code vs. comment vs.
//! string/char literal, (2) identifier/number/punctuation token boundaries
//! with 1-based line attribution, and (3) per-line
//! `// scls-lint: allow(<rule>[, <rule>...])` suppression directives
//! harvested from line comments. String *contents* are kept on their
//! tokens (the sink-surface rule reads the registry's name literals) but
//! never match identifier rules, so `"HashMap"` in a message is not a
//! finding.
//!
//! Mirrored line-for-line by the Python generator used to author
//! `lint/frozen.sha256` — behavioural changes here must keep the frozen
//! span extraction ([`crate::analysis::manifest`]) byte-stable.

use std::collections::BTreeMap;

/// Token class. `Str` covers string/byte-string/raw-string literals;
/// char literals and lifetimes produce no token at all.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    Ident,
    Num,
    Str,
    Punct,
}

/// One lexed token with its 1-based source line.
#[derive(Debug, Clone)]
pub struct Tok {
    pub line: u32,
    pub kind: TokKind,
    pub text: String,
    /// For `Num` tokens: the literal is a float (has a fraction, a decimal
    /// exponent, or an `f32`/`f64` suffix).
    pub is_float: bool,
}

/// Per-line suppressions: line number → rules allowed on that line.
pub type Suppressions = BTreeMap<u32, Vec<String>>;

/// Two-character operators lexed as one token (the rules only consume
/// `==`/`!=`/`::`, but lexing the rest keeps e.g. `<=` from emitting a
/// stray `=` that could pair into a phantom comparator).
const TWO_CHAR: [&str; 10] = ["==", "!=", "::", "<=", ">=", "->", "=>", "..", "&&", "||"];

fn is_ident_start(c: char) -> bool {
    c.is_ascii_alphabetic() || c == '_'
}

fn is_ident_cont(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Lex `src` into tokens plus the per-line suppression table.
pub fn lex(src: &str) -> (Vec<Tok>, Suppressions) {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut toks = Vec::new();
    let mut supp = Suppressions::new();
    let mut i = 0usize;
    let mut line = 1u32;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c == ' ' || c == '\t' || c == '\r' {
            i += 1;
            continue;
        }
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            let mut j = i + 2;
            while j < n && chars[j] != '\n' {
                j += 1;
            }
            let comment: String = chars[i + 2..j].iter().collect();
            scan_suppression(&comment, line, &mut supp);
            i = j;
            continue;
        }
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 1u32;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if chars[j] == '\n' {
                    line += 1;
                    j += 1;
                } else if chars[j] == '/' && j + 1 < n && chars[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if chars[j] == '*' && j + 1 < n && chars[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            i = j;
            continue;
        }
        if c == '"' {
            let start_line = line;
            let (j, nl, content) = consume_string(&chars, i);
            line += nl;
            toks.push(Tok {
                line: start_line,
                kind: TokKind::Str,
                text: content,
                is_float: false,
            });
            i = j;
            continue;
        }
        if c == '\'' {
            // Char literal (`'x'`, `'\n'`, `'\u{1F600}'`) or lifetime
            // (`'a`, `'_`). Escaped literals scan to the closing quote;
            // `'x'` is recognized by the quote two ahead; anything else is
            // a lifetime and is skipped without emitting a token.
            if i + 1 < n && chars[i + 1] == '\\' {
                let mut j = i + 2;
                if j < n {
                    j += 1; // the escaped character itself
                }
                while j < n && chars[j] != '\'' {
                    j += 1;
                }
                i = (j + 1).min(n);
                continue;
            }
            if i + 2 < n && chars[i + 2] == '\'' {
                i += 3;
                continue;
            }
            let mut j = i + 1;
            while j < n && is_ident_cont(chars[j]) {
                j += 1;
            }
            i = j;
            continue;
        }
        if is_ident_start(c) {
            let mut j = i + 1;
            while j < n && is_ident_cont(chars[j]) {
                j += 1;
            }
            let text: String = chars[i..j].iter().collect();
            // Raw/byte string prefixes: `r"..."`, `r#"..."#`, `b"..."`,
            // `br#"..."#`. The prefix ident is swallowed by the literal.
            if (text == "r" || text == "b" || text == "br")
                && j < n
                && (chars[j] == '"' || chars[j] == '#')
            {
                let start_line = line;
                let (k, nl, content) = consume_raw_string(&chars, j);
                if k > j {
                    line += nl;
                    toks.push(Tok {
                        line: start_line,
                        kind: TokKind::Str,
                        text: content,
                        is_float: false,
                    });
                    i = k;
                    continue;
                }
            }
            toks.push(Tok {
                line,
                kind: TokKind::Ident,
                text,
                is_float: false,
            });
            i = j;
            continue;
        }
        if c.is_ascii_digit() {
            let (j, is_float) = consume_number(&chars, i);
            toks.push(Tok {
                line,
                kind: TokKind::Num,
                text: chars[i..j].iter().collect(),
                is_float,
            });
            i = j;
            continue;
        }
        let two: String = chars[i..(i + 2).min(n)].iter().collect();
        if TWO_CHAR.contains(&two.as_str()) {
            toks.push(Tok {
                line,
                kind: TokKind::Punct,
                text: two,
                is_float: false,
            });
            i += 2;
            continue;
        }
        toks.push(Tok {
            line,
            kind: TokKind::Punct,
            text: c.to_string(),
            is_float: false,
        });
        i += 1;
    }
    (toks, supp)
}

/// Consume a `"..."` literal starting at the opening quote. Returns
/// (index past the closing quote, newlines crossed, raw content).
fn consume_string(chars: &[char], start: usize) -> (usize, u32, String) {
    let n = chars.len();
    let mut j = start + 1;
    let mut nl = 0u32;
    let mut content = String::new();
    while j < n {
        if chars[j] == '\\' {
            content.push(chars[j]);
            if j + 1 < n {
                content.push(chars[j + 1]);
            }
            j += 2;
            continue;
        }
        if chars[j] == '\n' {
            nl += 1;
            content.push('\n');
            j += 1;
            continue;
        }
        if chars[j] == '"' {
            return (j + 1, nl, content);
        }
        content.push(chars[j]);
        j += 1;
    }
    (n, nl, content)
}

/// Consume a raw string whose `#`/`"` run starts at `start` (just past the
/// `r`/`b`/`br` prefix). Returns (index past the close, newlines crossed,
/// content); a non-match (e.g. the raw identifier `r#match`) returns
/// `start` untouched so the caller falls back to the plain identifier.
fn consume_raw_string(chars: &[char], start: usize) -> (usize, u32, String) {
    let n = chars.len();
    let mut j = start;
    let mut hashes = 0usize;
    while j < n && chars[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j >= n || chars[j] != '"' {
        return (start, 0, String::new());
    }
    j += 1;
    let mut nl = 0u32;
    let mut content = String::new();
    while j < n {
        if chars[j] == '\n' {
            nl += 1;
            content.push('\n');
            j += 1;
            continue;
        }
        if chars[j] == '"' {
            let mut k = j + 1;
            let mut seen = 0usize;
            while k < n && seen < hashes && chars[k] == '#' {
                seen += 1;
                k += 1;
            }
            if seen == hashes {
                return (k, nl, content);
            }
        }
        content.push(chars[j]);
        j += 1;
    }
    (n, nl, content)
}

/// Consume a numeric literal starting at a digit. A `.` is part of the
/// number only when followed by a digit (so `1..5` and `1.max(2)` lex as
/// integer + punctuation), mirroring rustc closely enough for the rules.
fn consume_number(chars: &[char], start: usize) -> (usize, bool) {
    let n = chars.len();
    let mut j = start + 1;
    let mut is_float = false;
    if chars[start] == '0' && j < n && (chars[j] == 'x' || chars[j] == 'o' || chars[j] == 'b') {
        j += 1;
        while j < n && is_ident_cont(chars[j]) {
            j += 1;
        }
        return (j, false);
    }
    while j < n && (chars[j].is_ascii_digit() || chars[j] == '_') {
        j += 1;
    }
    if j < n && chars[j] == '.' && j + 1 < n && chars[j + 1].is_ascii_digit() {
        is_float = true;
        j += 1;
        while j < n && (chars[j].is_ascii_digit() || chars[j] == '_') {
            j += 1;
        }
    }
    if j < n && (chars[j] == 'e' || chars[j] == 'E') {
        let mut k = j + 1;
        if k < n && (chars[k] == '+' || chars[k] == '-') {
            k += 1;
        }
        if k < n && chars[k].is_ascii_digit() {
            is_float = true;
            j = k;
            while j < n && (chars[j].is_ascii_digit() || chars[j] == '_') {
                j += 1;
            }
        }
    }
    let suffix_start = j;
    while j < n && is_ident_cont(chars[j]) {
        j += 1;
    }
    let suffix: String = chars[suffix_start..j].iter().collect();
    if suffix == "f32" || suffix == "f64" {
        is_float = true;
    }
    (j, is_float)
}

/// Harvest `scls-lint: allow(rule[, rule...])` from one line comment's
/// text. Rule names are kebab-case; anything after the closing paren is
/// free-form justification and is ignored.
fn scan_suppression(comment: &str, line: u32, supp: &mut Suppressions) {
    let Some(pos) = comment.find("scls-lint:") else {
        return;
    };
    let rest = comment[pos + "scls-lint:".len()..].trim_start();
    let Some(inner) = rest.strip_prefix("allow(") else {
        return;
    };
    let Some(close) = inner.find(')') else {
        return;
    };
    for rule in inner[..close].split(',') {
        let rule = rule.trim();
        let well_formed = !rule.is_empty()
            && rule
                .chars()
                .all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '-');
        if well_formed {
            supp.entry(line).or_default().push(rule.to_string());
        }
    }
}

/// True when `rule` is suppressed on `line`.
pub fn is_allowed(supp: &Suppressions, line: u32, rule: &str) -> bool {
    supp.get(&line).is_some_and(|rules| rules.iter().any(|r| r == rule))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<(u32, String)> {
        lex(src)
            .0
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| (t.line, t.text))
            .collect()
    }

    #[test]
    fn comments_and_strings_emit_no_idents() {
        let src = "// HashMap here\nlet x = \"HashMap\";\n/* HashMap\n HashMap */ let y = 1;\n";
        let ids = idents(src);
        assert_eq!(
            ids,
            vec![(2, "let".into()), (2, "x".into()), (4, "let".into()), (4, "y".into())]
        );
    }

    #[test]
    fn string_tokens_keep_content_and_lines_advance() {
        let src = "let a = \"two\nlines\";\nlet b = 2;\n";
        let (toks, _) = lex(src);
        let s: Vec<&Tok> = toks.iter().filter(|t| t.kind == TokKind::Str).collect();
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].text, "two\nlines");
        assert_eq!(s[0].line, 1);
        let b: Vec<&Tok> = toks.iter().filter(|t| t.text == "b").collect();
        assert_eq!(b[0].line, 3, "newline inside the string must count");
    }

    #[test]
    fn raw_strings_and_lifetimes() {
        let src = "let s = r#\"Instant::now() \"quoted\" \"#; fn f<'a>(x: &'a str) {}\n";
        let (toks, _) = lex(src);
        assert!(!toks.iter().any(|t| t.kind == TokKind::Ident && t.text == "Instant"));
        let raw: Vec<&Tok> = toks.iter().filter(|t| t.kind == TokKind::Str).collect();
        assert_eq!(raw.len(), 1);
        assert!(raw[0].text.contains("Instant::now()"));
        // The lifetime `'a` emits nothing; `a` must not appear as an ident.
        assert!(!toks.iter().any(|t| t.kind == TokKind::Ident && t.text == "a"));
    }

    #[test]
    fn char_literals_do_not_eat_code() {
        let src = "let c = 'x'; let nl = '\\n'; let d = c;\n";
        let ids = idents(src);
        assert!(ids.iter().any(|(_, t)| t == "d"));
        assert_eq!(ids.iter().filter(|(_, t)| t == "let").count(), 3);
    }

    #[test]
    fn number_classification() {
        let cases = [
            ("1", false),
            ("10_000", false),
            ("0xff", false),
            ("0b1010", false),
            ("1.5", true),
            ("2.0f64", true),
            ("1e3", true),
            ("1.5e-3", true),
            ("3f64", true),
            ("128u32", false),
        ];
        for (lit, want) in cases {
            let (toks, _) = lex(&format!("let x = {lit};"));
            let num = toks.iter().find(|t| t.kind == TokKind::Num).unwrap();
            assert_eq!(num.is_float, want, "{lit}");
            assert_eq!(num.text, lit, "{lit}");
        }
    }

    #[test]
    fn range_and_method_dots_are_not_fractions() {
        let (toks, _) = lex("for i in 1..5 { x = 1.max(2); }");
        let nums: Vec<&Tok> = toks.iter().filter(|t| t.kind == TokKind::Num).collect();
        assert!(nums.iter().all(|t| !t.is_float), "{nums:?}");
    }

    #[test]
    fn two_char_operators_lex_whole() {
        let (toks, _) = lex("a == b; c != d; e::f; g <= 1.0;");
        let puncts: Vec<&str> = toks
            .iter()
            .filter(|t| t.kind == TokKind::Punct)
            .map(|t| t.text.as_str())
            .collect();
        assert!(puncts.contains(&"=="));
        assert!(puncts.contains(&"!="));
        assert!(puncts.contains(&"::"));
        assert!(puncts.contains(&"<="));
        assert!(!puncts.contains(&"="), "no stray `=` from `<=`: {puncts:?}");
    }

    #[test]
    fn suppressions_parse_per_line() {
        let src = "let m = x; // scls-lint: allow(hash-order): keyed, never iterated\n\
                   let n = y; // scls-lint: allow(float-cmp, wall-clock)\n\
                   let o = z; // plain comment\n";
        let (_, supp) = lex(src);
        assert!(is_allowed(&supp, 1, "hash-order"));
        assert!(!is_allowed(&supp, 1, "float-cmp"));
        assert!(is_allowed(&supp, 2, "float-cmp"));
        assert!(is_allowed(&supp, 2, "wall-clock"));
        assert!(!is_allowed(&supp, 3, "hash-order"));
    }
}
