//! Request trace generation and (de)serialization.
//!
//! The paper sends requests "in the order they actually arrived" from the
//! CodeFuse trace, with Poisson arrival times at various rates for 10
//! minutes (§5.1 Workflow). We generate the equivalent synthetic trace:
//! exponential inter-arrivals at `rate` req/s for `duration` seconds, with
//! input/generation lengths drawn from the workload distributions.

use crate::core::Request;
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::distributions::WorkloadKind;

#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub kind: WorkloadKind,
    /// Mean arrival rate, requests/second.
    pub rate: f64,
    /// Trace duration in seconds (paper: 600).
    pub duration: f64,
    /// Maximal raw input length; longer inputs are truncated (paper: 1024).
    pub max_input_len: u32,
    /// Maximal generation length limit (paper: 1024). Used as the length
    /// distribution clip; the serving-time cap is enforced by the engine.
    pub max_gen_len: u32,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            kind: WorkloadKind::CodeFuse,
            rate: 20.0,
            duration: 600.0,
            max_input_len: 1024,
            max_gen_len: 1024,
            seed: 42,
        }
    }
}

/// A generated request trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub requests: Vec<Request>,
    pub config_rate: f64,
    pub duration: f64,
}

impl Trace {
    /// Poisson-process trace with lengths from the workload distributions.
    pub fn generate(cfg: &TraceConfig) -> Trace {
        let mut rng = Rng::new(cfg.seed);
        let input_dist = cfg.kind.input_dist(cfg.max_input_len);
        let gen_dist = cfg.kind.gen_dist(cfg.max_gen_len);

        let mut requests = Vec::new();
        let mut t = 0.0;
        let mut id = 0u64;
        loop {
            t += rng.exponential(cfg.rate);
            if t >= cfg.duration {
                break;
            }
            let input_len = input_dist.sample(&mut rng);
            let gen_len = gen_dist.sample(&mut rng);
            requests.push(Request::new(id, t, input_len, gen_len));
            id += 1;
        }
        Trace {
            requests,
            config_rate: cfg.rate,
            duration: cfg.duration,
        }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    // ---- persistence (JSON) ------------------------------------------

    pub fn to_json(&self) -> Json {
        let reqs: Vec<Json> = self
            .requests
            .iter()
            .map(|r| {
                let mut o = Json::obj();
                o.set("id", r.id)
                    .set("arrival", r.arrival)
                    .set("input_len", r.input_len)
                    .set("gen_len", r.target_gen_len);
                // Tenancy/SLO keys only when non-default, so SLO-free
                // traces serialize byte-identically to the legacy format.
                if r.tenant != 0 {
                    o.set("tenant", r.tenant);
                }
                if r.priority != 0 {
                    o.set("priority", r.priority as u32);
                }
                if let Some(t) = r.slo.ttft {
                    o.set("slo_ttft", t);
                }
                if let Some(t) = r.slo.tpot {
                    o.set("slo_tpot", t);
                }
                if let Some(d) = r.slo.deadline {
                    o.set("slo_deadline", d);
                }
                o
            })
            .collect();
        let mut o = Json::obj();
        o.set("rate", self.config_rate)
            .set("duration", self.duration)
            .set("requests", Json::Arr(reqs));
        o
    }

    pub fn from_json(j: &Json) -> anyhow::Result<Trace> {
        let rate = j
            .get("rate")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("trace: missing rate"))?;
        let duration = j
            .get("duration")
            .and_then(Json::as_f64)
            .ok_or_else(|| anyhow::anyhow!("trace: missing duration"))?;
        let arr = j
            .get("requests")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("trace: missing requests"))?;
        let mut requests = Vec::with_capacity(arr.len());
        for r in arr {
            let get_u32 = |k: &str| -> anyhow::Result<u32> {
                r.get(k)
                    .and_then(Json::as_i64)
                    .map(|x| x as u32)
                    .ok_or_else(|| anyhow::anyhow!("trace request: missing {k}"))
            };
            let mut req = Request::new(
                r.get("id")
                    .and_then(Json::as_i64)
                    .ok_or_else(|| anyhow::anyhow!("trace request: missing id"))?
                    as u64,
                r.get("arrival")
                    .and_then(Json::as_f64)
                    .ok_or_else(|| anyhow::anyhow!("trace request: missing arrival"))?,
                get_u32("input_len")?,
                get_u32("gen_len")?,
            );
            // Optional tenancy/SLO keys: absent in legacy traces, which
            // load with the SLO-free defaults.
            if let Some(t) = r.get("tenant").and_then(Json::as_i64) {
                req.tenant = t as u32;
            }
            if let Some(p) = r.get("priority").and_then(Json::as_i64) {
                req.priority = p as u8;
            }
            req.slo.ttft = r.get("slo_ttft").and_then(Json::as_f64);
            req.slo.tpot = r.get("slo_tpot").and_then(Json::as_f64);
            req.slo.deadline = r.get("slo_deadline").and_then(Json::as_f64);
            requests.push(req);
        }
        Ok(Trace {
            requests,
            config_rate: rate,
            duration,
        })
    }

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json().to_string_compact())?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Trace> {
        let s = std::fs::read_to_string(path)?;
        Trace::from_json(&Json::parse(&s)?)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> TraceConfig {
        TraceConfig {
            duration: 60.0,
            ..Default::default()
        }
    }

    #[test]
    fn arrivals_sorted_and_bounded() {
        let t = Trace::generate(&cfg());
        for w in t.requests.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        assert!(t.requests.iter().all(|r| r.arrival < 60.0));
    }

    #[test]
    fn rate_approximately_respected() {
        let t = Trace::generate(&TraceConfig {
            duration: 600.0,
            rate: 20.0,
            ..cfg()
        });
        let n = t.len() as f64;
        // Poisson(12000): ±4 sigma ≈ ±440
        assert!((n - 12_000.0).abs() < 500.0, "n = {n}");
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Trace::generate(&cfg());
        let b = Trace::generate(&cfg());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.arrival, y.arrival);
            assert_eq!(x.input_len, y.input_len);
            assert_eq!(x.target_gen_len, y.target_gen_len);
        }
        let c = Trace::generate(&TraceConfig {
            seed: 7,
            ..cfg()
        });
        assert_ne!(
            a.requests.iter().map(|r| r.input_len).collect::<Vec<_>>(),
            c.requests.iter().map(|r| r.input_len).collect::<Vec<_>>()
        );
    }

    #[test]
    fn lengths_respect_limits() {
        let t = Trace::generate(&TraceConfig {
            max_input_len: 128,
            max_gen_len: 64,
            ..cfg()
        });
        assert!(t.requests.iter().all(|r| r.input_len <= 128));
        assert!(t.requests.iter().all(|r| r.target_gen_len <= 64));
    }

    #[test]
    fn slo_fields_roundtrip_and_stay_off_the_wire_when_default() {
        let mut t = Trace::generate(&TraceConfig {
            duration: 5.0,
            ..cfg()
        });
        // SLO-free serialization has no tenancy keys at all.
        let text = t.to_json().to_string_compact();
        for key in ["tenant", "priority", "slo_ttft", "slo_tpot", "slo_deadline"] {
            assert!(!text.contains(key), "{key} leaked into an SLO-free trace");
        }
        // Legacy text (no keys) loads with defaults.
        let legacy = Trace::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert!(legacy
            .requests
            .iter()
            .all(|r| r.tenant == 0 && r.priority == 0 && r.slo.is_none()));
        // Stamped fields round-trip exactly.
        t.requests[0].tenant = 3;
        t.requests[0].priority = 3;
        t.requests[0].slo.ttft = Some(1.25);
        t.requests[0].slo.deadline = Some(90.5);
        let back = Trace::from_json(&t.to_json()).unwrap();
        assert_eq!(back.requests[0].tenant, 3);
        assert_eq!(back.requests[0].priority, 3);
        assert_eq!(back.requests[0].slo.ttft, Some(1.25));
        assert_eq!(back.requests[0].slo.tpot, None);
        assert_eq!(back.requests[0].slo.deadline, Some(90.5));
    }

    #[test]
    fn json_roundtrip() {
        let t = Trace::generate(&TraceConfig {
            duration: 5.0,
            ..cfg()
        });
        let j = t.to_json();
        let back = Trace::from_json(&j).unwrap();
        assert_eq!(back.len(), t.len());
        for (x, y) in t.requests.iter().zip(&back.requests) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.input_len, y.input_len);
            assert_eq!(x.target_gen_len, y.target_gen_len);
            assert!((x.arrival - y.arrival).abs() < 1e-9);
        }
    }
}
