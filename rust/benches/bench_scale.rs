//! Scale benchmark: drain a paper-shaped 1M-request trace on a 64-worker
//! SCLS cluster and record the coordinator's real cost (`cargo bench
//! --bench bench_scale`).
//!
//! This is the perf trajectory anchor for the coordinator hot paths: the
//! DP batcher, the schedule-tick loop, and the generic policy-driven DES
//! loop all run at production pool sizes here (the adaptive interval
//! stretches under backlog, so late ticks batch hundreds of thousands of
//! pooled requests at once). The run streams through a `Tally` metrics
//! sink (the same observer API the figure cells and the real driver
//! feed), prints the events/sec delta against the checked-in
//! `BENCH_scale.json` baseline, then rewrites that baseline in place so
//! `git diff` shows the drift.
//!
//! A second, smaller P-CB drain (prediction-aware continuous batching
//! with the oracle predictor) rides along so the predictor subsystem's
//! overhead shows up in the same events/sec trajectory — its row lands
//! under the `p_cb` key of `BENCH_scale.json`. A third drain runs P-SCLS
//! with `--pred-corrected-dp` and the `online:4096` predictor (the
//! corrected branch-and-bound planner's production shape) under the
//! `p_scls_corrected` key, so the regression gate covers the corrected
//! path too.
//!
//! Knobs (env): SCLS_SCALE_REQUESTS [1000000], SCLS_SCALE_WORKERS [64],
//! SCLS_SCALE_RATE [2000], SCLS_SCALE_SLICE [128],
//! SCLS_SCALE_PCB_REQUESTS [200000], SCLS_SCALE_PSCLS_REQUESTS [200000].
//!
//! Enforcement: set SCLS_SCALE_MAX_REGRESSION to a percentage (e.g. `10`)
//! and the bench *fails* when events/sec drops more than that against a
//! non-provisional, same-shape baseline — the events/sec delta is then a
//! gate, not just a printout. A gated run that came in at-or-below a
//! *valid* anchor (non-provisional, same shape) leaves it untouched — a
//! passing-but-slower run must not ratchet the anchor down night after
//! night — while improvements beyond the gate margin re-anchor upward
//! (within-margin wiggle is treated as noise), and provisional or
//! shape-mismatched baselines are always regenerated (without the
//! `provisional` flag), so even a gated-only workflow arms the gate on
//! its first real-toolchain run.

use std::time::Instant;

use scls::engine::presets::{EngineKind, EnginePreset};
use scls::metrics::Tally;
use scls::predictor::PredictorSpec;
use scls::sim::driver::{SimConfig, Simulation};
use scls::util::json::Json;
use scls::workload::distributions::WorkloadKind;
use scls::workload::{Trace, TraceConfig};

fn env_u64(key: &str, default: u64) -> u64 {
    std::env::var(key)
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(default)
}

/// The baseline lives next to Cargo.toml regardless of the bench's cwd.
fn baseline_path() -> String {
    format!("{}/BENCH_scale.json", env!("CARGO_MANIFEST_DIR"))
}

fn main() {
    // A malformed gate value must not silently disarm the gate (nor arm a
    // nonsensical one): warn loudly and run un-gated.
    let max_regression = std::env::var("SCLS_SCALE_MAX_REGRESSION").ok().and_then(|s| {
        match s.parse::<f64>() {
            Ok(v) if v.is_finite() && v >= 0.0 => Some(v),
            _ => {
                eprintln!(
                    "warning: ignoring invalid SCLS_SCALE_MAX_REGRESSION='{s}' \
                     (want a non-negative percentage, e.g. 10) — gate DISARMED"
                );
                None
            }
        }
    });
    let n_requests = env_u64("SCLS_SCALE_REQUESTS", 1_000_000) as usize;
    let workers = env_u64("SCLS_SCALE_WORKERS", 64) as usize;
    let rate = env_u64("SCLS_SCALE_RATE", 2000) as f64;
    let slice_len = env_u64("SCLS_SCALE_SLICE", 128) as u32;

    // Paper-shaped workload: CodeFuse length distributions, Poisson
    // arrivals. Generate slightly long, then truncate to the exact count so
    // the headline number is stable across RNG drift.
    let gen_start = Instant::now();
    let mut trace = Trace::generate(&TraceConfig {
        kind: WorkloadKind::CodeFuse,
        rate,
        duration: (n_requests as f64 / rate) * 1.05 + 1.0,
        max_input_len: 1024,
        max_gen_len: 1024,
        seed: 42,
    });
    trace.requests.truncate(n_requests);
    let n = trace.len();
    println!(
        "bench_scale: {} requests generated in {:.2} s ({} workers, rate {rate}, S={slice_len})",
        n,
        gen_start.elapsed().as_secs_f64(),
        workers
    );

    let preset = EnginePreset::paper(EngineKind::Ds);
    let sim = Simulation::new(SimConfig::new(workers, preset, 1024, 42));
    let mut tally = Tally::default();

    let t0 = Instant::now();
    let m = sim
        .run_named_with_sink(&trace, "SCLS", slice_len, &mut tally)
        .expect("SCLS is a built-in policy");
    let wall = t0.elapsed().as_secs_f64();

    assert_eq!(m.completed.len(), n, "scale drain lost requests");
    assert_eq!(tally.completions as usize, n, "sink missed completions");
    let events_per_sec = m.events as f64 / wall.max(1e-9);
    let s = m.summarize();

    println!("drained {} requests in {wall:.3} s wall", tally.completions);
    println!("events            {}", m.events);
    println!("events/sec        {events_per_sec:.0}");
    println!("peak pool size    {}", tally.peak_pool);
    println!("batches served    {}", tally.batches);
    println!("virtual makespan  {:.1} s", m.makespan);
    println!("virtual thpt      {:.2} req/s", s.throughput);

    // Regression check against the checked-in baseline (ROADMAP: diff
    // events/sec whenever batcher/, sim/, or scheduler/ change). Gated
    // runs protect a *valid* anchor (non-provisional, same shape) from
    // being rewritten; provisional or shape-mismatched baselines are
    // regenerated even when gated, so a gated-only workflow still arms
    // the gate on its first real run.
    let path = baseline_path();
    let mut protect_baseline = false;
    let baseline = std::fs::read_to_string(&path).ok().and_then(|s| Json::parse(&s).ok());
    match &baseline {
        Some(base) => {
            let provisional = matches!(base.get("provisional"), Some(Json::Bool(true)));
            let prev = base.get("events_per_sec").and_then(|j| j.as_f64());
            // Deltas are only meaningful against the same workload shape:
            // every knob must match the baseline, not just the request count.
            let knob = |key: &str| base.get(key).and_then(|j| j.as_f64());
            let same_shape = knob("requests") == Some(n as f64)
                && knob("workers") == Some(workers as f64)
                && knob("rate") == Some(rate)
                && knob("slice_len") == Some(slice_len as f64);
            match prev {
                Some(prev) if provisional => {
                    if max_regression.is_some() && !same_shape {
                        // A gated quick run with overridden shape knobs
                        // must not anchor the provisional baseline at the
                        // wrong shape (that would leave every later
                        // default-shape gated run in the mismatch arm and
                        // permanently disarm the gate). Leave arming to a
                        // run at the baseline's own shape.
                        protect_baseline = true;
                        println!(
                            "baseline is provisional and this gated run overrides the workload \
                             shape — leaving the placeholder for a matching-shape run to anchor"
                        );
                    } else {
                        println!(
                            "baseline is provisional (structure only, authored without a toolchain); \
                             this run anchors events/sec at {events_per_sec:.0} (placeholder was {prev:.0})"
                        );
                    }
                }
                Some(prev) if same_shape => {
                    let delta = (events_per_sec - prev) / prev * 100.0;
                    println!(
                        "events/sec delta vs baseline: {delta:+.2}% (baseline {prev:.0}, now {events_per_sec:.0})"
                    );
                    if let Some(max_reg) = max_regression {
                        assert!(
                            delta >= -max_reg,
                            "events/sec regressed {delta:.2}% (> {max_reg}% allowed): \
                             baseline {prev:.0}, now {events_per_sec:.0}"
                        );
                        // Protect the anchor inside the noise band: only a
                        // genuine improvement (beyond the gate margin
                        // itself) re-anchors upward. Re-anchoring on any
                        // positive delta would ratchet the anchor to the
                        // historical noise maximum and fail healthy runs;
                        // never re-anchoring would let a later regression
                        // hide inside real-speedup headroom.
                        protect_baseline = delta <= max_reg;
                    }
                }
                Some(prev) => {
                    println!(
                        "baseline used a different workload shape (requests/workers/rate/slice_len) \
                         — no delta; baseline events/sec was {prev:.0}"
                    );
                    // A gated quick run with overridden shape knobs must
                    // not clobber the valid anchor the gate exists to
                    // protect (only provisional/missing baselines need
                    // regenerating to arm the gate).
                    protect_baseline = max_regression.is_some();
                }
                None => println!("baseline at {path} has no events_per_sec field"),
            }
        }
        None => println!("no baseline at {path}; this run establishes it"),
    }

    // ---- P-CB row: prediction-aware continuous batching at scale -------
    // A smaller drain (per-iteration events make P-CB's event count much
    // denser than SCLS ticks), same workload shape, oracle predictor.
    let pcb_n = (env_u64("SCLS_SCALE_PCB_REQUESTS", 200_000) as usize).min(n);
    let pcb_trace = scls::workload::Trace {
        requests: trace.requests[..pcb_n].to_vec(),
        config_rate: trace.config_rate,
        duration: trace.duration,
    };
    let mut pcb_tally = Tally::default();
    let t1 = Instant::now();
    let pm = sim
        .run_named_with_sink(&pcb_trace, "P-CB", slice_len, &mut pcb_tally)
        .expect("P-CB is a built-in policy");
    let pcb_wall = t1.elapsed().as_secs_f64();
    assert_eq!(pm.completed.len(), pcb_n, "P-CB drain lost requests");
    let pcb_eps = pm.events as f64 / pcb_wall.max(1e-9);
    println!();
    println!(
        "P-CB (oracle): drained {} requests in {pcb_wall:.3} s wall",
        pcb_tally.completions
    );
    println!("P-CB events       {}", pm.events);
    println!("P-CB events/sec   {pcb_eps:.0}");
    println!(
        "P-CB mispredicts  under {} / over {} / wasted {} tok",
        pm.underpredicted, pm.overpredicted, pm.wasted_kv_token_steps
    );

    // ---- P-SCLS corrected row: branch-and-bound corrected DP at scale ---
    // Same workload shape, online:4096 predictor, --pred-corrected-dp: the
    // production shape of the corrected planner (per-rung DP with stamped
    // predictions), so its events/sec lands in the gate's trajectory.
    let pscls_n = (env_u64("SCLS_SCALE_PSCLS_REQUESTS", 200_000) as usize).min(n);
    let pscls_trace = scls::workload::Trace {
        requests: trace.requests[..pscls_n].to_vec(),
        config_rate: trace.config_rate,
        duration: trace.duration,
    };
    let pspec = PredictorSpec::parse("online:4096", WorkloadKind::CodeFuse)
        .expect("online:4096 is a valid predictor spelling");
    let pscls_sim = Simulation::new(
        SimConfig::new(workers, EnginePreset::paper(EngineKind::Ds), 1024, 42)
            .with_predictor(pspec)
            .with_pred_corrected_dp(true),
    );
    let mut pscls_tally = Tally::default();
    let t2 = Instant::now();
    let sm = pscls_sim
        .run_named_with_sink(&pscls_trace, "P-SCLS", slice_len, &mut pscls_tally)
        .expect("P-SCLS is a built-in policy");
    let pscls_wall = t2.elapsed().as_secs_f64();
    assert_eq!(sm.completed.len(), pscls_n, "P-SCLS corrected drain lost requests");
    if pscls_n >= 1000 {
        // The drain must actually exercise the corrected planner, or the
        // row gates nothing.
        assert!(sm.corrected_batches > 0, "corrected DP never fired on the P-SCLS drain");
    }
    let pscls_eps = sm.events as f64 / pscls_wall.max(1e-9);
    println!();
    println!(
        "P-SCLS corrected (online:4096): drained {} requests in {pscls_wall:.3} s wall",
        pscls_tally.completions
    );
    println!("P-SCLS events     {}", sm.events);
    println!("P-SCLS events/sec {pscls_eps:.0}");
    println!(
        "P-SCLS corrected batches {} / refits {} / under {} / over {}",
        sm.corrected_batches, sm.predictor_refits, sm.underpredicted, sm.overpredicted
    );
    // Row-level gate: a valid (non-provisional) baseline with a matching
    // p_scls_corrected row must not regress beyond the same margin.
    if let (Some(max_reg), Some(base)) = (max_regression, baseline.as_ref()) {
        let provisional = matches!(base.get("provisional"), Some(Json::Bool(true)));
        let row = base.get("p_scls_corrected");
        let row_knob = |key: &str| row.and_then(|r| r.get(key)).and_then(|v| v.as_f64());
        if !provisional && row_knob("requests") == Some(pscls_n as f64) {
            if let Some(prev) = row_knob("events_per_sec").filter(|&v| v > 0.0) {
                let delta = (pscls_eps - prev) / prev * 100.0;
                println!(
                    "p_scls_corrected events/sec delta vs baseline: {delta:+.2}% \
                     (baseline {prev:.0}, now {pscls_eps:.0})"
                );
                assert!(
                    delta >= -max_reg,
                    "p_scls_corrected events/sec regressed {delta:.2}% (> {max_reg}% allowed): \
                     baseline {prev:.0}, now {pscls_eps:.0}"
                );
            }
        }
    }

    let mut j = Json::obj();
    j.set("requests", n as u64)
        .set("workers", workers as u64)
        .set("rate", rate)
        .set("slice_len", slice_len)
        .set("wall_seconds", wall)
        .set("events", m.events)
        .set("events_per_sec", events_per_sec)
        .set("peak_pool", m.peak_pool as u64)
        .set("batches", m.batches.len() as u64)
        .set("virtual_makespan", m.makespan)
        .set("virtual_throughput", s.throughput)
        .set("completed", s.completed as u64);
    let mut pcb = Json::obj();
    pcb.set("requests", pcb_n as u64)
        .set("wall_seconds", pcb_wall)
        .set("events", pm.events)
        .set("events_per_sec", pcb_eps)
        .set("underpredicted", pm.underpredicted)
        .set("overpredicted", pm.overpredicted)
        .set("wasted_kv_token_steps", pm.wasted_kv_token_steps)
        .set("virtual_throughput", pm.summarize().throughput);
    j.set("p_cb", pcb);
    let mut pscls = Json::obj();
    pscls
        .set("requests", pscls_n as u64)
        .set("wall_seconds", pscls_wall)
        .set("events", sm.events)
        .set("events_per_sec", pscls_eps)
        .set("corrected_batches", sm.corrected_batches)
        .set("predictor_refits", sm.predictor_refits)
        .set("underpredicted", sm.underpredicted)
        .set("overpredicted", sm.overpredicted)
        .set("virtual_throughput", sm.summarize().throughput);
    j.set("p_scls_corrected", pscls);
    if protect_baseline {
        // Gated run against a valid anchor: rewriting it would let a
        // passing-but-slower run ratchet the anchor down until a
        // cumulative regression never trips.
        println!("gated run (SCLS_SCALE_MAX_REGRESSION set): baseline at {path} left untouched");
    } else {
        std::fs::write(&path, j.to_string_pretty()).expect("write BENCH_scale.json");
        println!("wrote {path}");
    }
}
