//! Slice-length tuning: reproduce the §5.5 trade-off study and pick S.
//!
//! The slice length S is SCLS's single tuning knob. Too small → every
//! request is rescheduled many times and pays repeated padding + prefill
//! recomputation; too large → batches shrink (Eq. 8), completed requests
//! wait, invalid tokens grow, and early returns break the serving-time
//! estimate (Figs. 18–21). This example sweeps S and prints the resulting
//! trade-off surface, then recommends the knee.
//!
//! Run with: `cargo run --release --example slice_tuning [-- --engine hf]`

use scls::bench::figures::{run_cell, FigureConfig};
use scls::engine::presets::EngineKind;
use scls::util::cli::Args;

fn main() {
    let args = Args::from_env();
    let kind = match args.str_or("engine", "ds") {
        "hf" | "HF" => EngineKind::Hf,
        _ => EngineKind::Ds,
    };
    let rate = args.f64_or("rate", 20.0);
    let fc = FigureConfig::quick(args.f64_or("quick", 0.2));
    let slices: Vec<u32> = args.u32_list_or("slices", &[16, 32, 64, 128, 192, 256, 384, 512]);

    println!(
        "slice_tuning: SCLS on {} at rate {rate}, {:.0}-s trace\n",
        kind.name(),
        fc.duration
    );
    println!(
        "{:>5} {:>9} {:>8} {:>8} {:>8} {:>8} {:>9} {:>7} {:>7}",
        "S", "thpt", "avgRT", "p95RT", "batch", "pads", "invalid", "early", "CTstd"
    );

    let mut best: Option<(u32, f64)> = None;
    for &s_len in &slices {
        let s = run_cell(&fc, kind, "SCLS", rate, s_len);
        println!(
            "{:>5} {:>9.2} {:>8.1} {:>8.1} {:>8.1} {:>8.1} {:>9.1} {:>7.4} {:>7.1}",
            s_len,
            s.throughput,
            s.avg_response_time,
            s.p95_response_time,
            s.avg_batch_size,
            s.avg_pad_tokens,
            s.avg_invalid_tokens,
            s.early_return_ratio,
            s.ct_std
        );
        if best.map(|(_, t)| s.throughput > t).unwrap_or(true) {
            best = Some((s_len, s.throughput));
        }
    }

    let (s_best, t_best) = best.unwrap();
    println!(
        "\nbest slice length: S = {s_best} ({t_best:.2} req/s). The paper lands on \
         S = 128 for the 1024-token limit — an interior knee, with throughput \
         falling off on both ends (Fig. 18)."
    );
}
