//! The built-in [`SchedulingPolicy`] implementations.
//!
//! * [`SlicedPolicy`] — the whole sliced family (SLS, SO, PM, AB, LB,
//!   SCLS): static batching workers driven by a [`SlicedCoordinator`]
//!   built from a `SchedulerSpec`'s four axes.
//! * [`IlsPolicy`] — the DeepSpeed-FastGen-style iteration-level baseline
//!   (continuous batching, conservative parallel cap, §5.1).
//! * [`SclsCbPolicy`] — the §7 extension: slice-level scheduling over
//!   continuous batching with precise per-slice memory admission and
//!   memory-balanced placement.
//! * [`PredictiveSlicedPolicy`] (P-SCLS) — SCLS seeded by a
//!   [`LengthPredictor`]: each request enters the slice ladder at the rung
//!   matching its predicted length bucket instead of the bottom, with
//!   under-predictions re-queued one rung at a time.
//! * [`PredictiveCbPolicy`] (P-CB) — continuous batching that admits
//!   against *predicted* KV demand instead of the worst case, with
//!   eviction/re-admission recovery when predictions fall short.
//!
//! The SLO-aware policies (D-SCLS, P-SRPT, SW-SLO) live in
//! [`crate::sim::slo_policies`] and reuse this module's static-batching
//! serving helpers ([`start_static_batch`] / [`settle_batch`]).
//!
//! Each pre-existing policy is a faithful port of the corresponding
//! pre-trait driver loop (`sim::reference`); the differential suite
//! (`tests/props_policy_differential.rs`) asserts the ports are
//! byte-identical on the full `RunMetrics` event log.
//!
//! **Elastic fleet.** [`SlicedPolicy`], [`IlsPolicy`],
//! [`PredictiveSlicedPolicy`], [`SclsCbPolicy`], and
//! [`PredictiveCbPolicy`] implement the optional
//! `on_worker_join`/`on_worker_lost` hooks: joins add cold workers under
//! fresh (never-reused) indices, drains stop accepting and migrate queued
//! work at the slice boundary, and crashes reclaim everything the dead
//! worker held — re-queued with generation advanced to the last completed
//! slice/iteration boundary, so at most one slice of work is lost per
//! surviving request (the structural gift of slicing: every boundary is a
//! checkpoint). The CB pair reclaims its running set via the worker's
//! `abandon` (re-prefill over input + generated; P-CB keeps the stale
//! prediction and lets the evict/double/re-admit ladder re-calibrate the
//! reservation). The coordinator-backed pair ([`SlicedPolicy`], P-SCLS)
//! additionally implements `on_coordinator_crash`: the successor rebuilds
//! pools, ledgers, and deficit counters from authoritative worker-side
//! reports plus the arrival log (see
//! [`SlicedCoordinator::rebuild_after_crash`]).
//!
//! **KV-transfer cost.** With `SimConfig::kv_transfer` set, every
//! migrated (queued) request is charged a modeled transfer stall over its
//! resident context before it is servable on the new worker — static
//! policies bank the stall as per-request debt paid at the next serving
//! start, continuous policies fold it into the next iteration arm. The
//! resident tokens are counted in `kv_tokens_migrated` even without a
//! cost model. On fault-free traces every policy stays byte-identical to
//! the pre-elastic code.

use std::collections::{BTreeMap, VecDeque};

use crate::batcher::{dp_batch_sorted_into, fcfs_batches, DpBatcherConfig, DpScratch};
use crate::core::{Batch, BatchOutcome, Request};
use crate::engine::continuous::ContinuousWorker;
use crate::engine::continuous_pred::PredictiveContinuousWorker;
use crate::engine::continuous_scls::SlicedContinuousWorker;
use crate::engine::presets::EnginePreset;
use crate::engine::sim::SimEngine;
use crate::estimator::{MemoryEstimator, ServingTimeEstimator, TransferCost};
use crate::metrics::{BatchRecord, FleetEventKind, FleetRecord, PredictionRecord, RunMetrics};
use crate::offloader::{LoadLedger, RoundRobin};
use crate::predictor::LengthPredictor;
use crate::scheduler::coordinator::SlicedCoordinator;
use crate::scheduler::fleet::{WorkerHealth, WorkerLedger, WorkerReport};
use crate::scheduler::policy::{SchedulingPolicy, SimCtx, WorkerLoss};
use crate::scheduler::spec::{BatchingSpec, IntervalSpec, OffloadSpec, SchedulerSpec};
use crate::scheduler::{IntervalController, RequestPool};
use crate::sim::driver::{fitted_estimator, SimConfig};

// ---------------------------------------------------------------------------
// Shared static-batching serving start
// ---------------------------------------------------------------------------

/// A batch in flight on one static-batching worker: the batch paired with
/// the slice outcome the engine already rolled, **not yet applied** to the
/// requests. Outcomes are applied by [`settle_batch`] when the completion
/// event fires — so a crash before the boundary can simply drop the slot's
/// outcome and recover the requests in their exact last-boundary state
/// (`input_len == orig_input_len + generated`), losing at most the one
/// interrupted slice.
pub(crate) struct ServingSlot {
    pub(crate) batch: Batch,
    outcome: BatchOutcome,
    /// Batch input length at serving start (the padding target).
    li: u32,
}

impl ServingSlot {
    /// Tokens this slice will deliver across the whole batch (telemetry:
    /// the per-worker served-token share).
    pub(crate) fn new_tokens_total(&self) -> u64 {
        self.outcome
            .per_request
            .iter()
            .map(|o| o.new_tokens as u64)
            .sum()
    }
}

/// Serving-start accounting shared by every static-batching policy
/// (sliced family and P-SCLS): serve one slice of `iter_limit` iterations,
/// log the batch record, park the batch + outcome in the worker's serving
/// slot, and schedule the completion event. Request state is deliberately
/// untouched until [`settle_batch`] at done-time. `stall` is the
/// KV-transfer debt owed by the batch's migrated members (0 on fault-free
/// runs — the completion time is then bit-identical to the stall-free
/// code): the batch cannot start until the slowest transfer lands, so the
/// stall shifts the completion event without touching the engine's
/// recorded serve time.
pub(crate) fn start_static_batch(
    engine: &mut SimEngine,
    serving: &mut Option<ServingSlot>,
    w: usize,
    batch: Batch,
    iter_limit: u32,
    stall: f64,
    ctx: &mut SimCtx,
) {
    debug_assert!(serving.is_none(), "worker {w} already serving");
    let li = batch.input_len();
    let outcome = engine.serve_slice(&batch, iter_limit);
    ctx.record_batch(BatchRecord {
        start: ctx.now,
        worker: w,
        size: batch.size() as u32,
        input_len: li,
        pad_tokens: batch.pad_tokens(),
        est_serve_time: batch.est_serve_time,
        actual_serve_time: outcome.duration,
        early_return: outcome.early_return,
    });
    let done_at = if stall > 0.0 {
        ctx.now + stall + outcome.duration
    } else {
        ctx.now + outcome.duration
    };
    *serving = Some(ServingSlot { batch, outcome, li });
    ctx.complete_at(done_at, w);
}

/// Charge one migrated request's KV-transfer cost: its full resident
/// context (input + everything generated so far — what the successor
/// worker must hold before serving it) counts as migrated tokens, and the
/// configured cost model prices the stall (0 without a model — the tokens
/// are still counted). Returns the stall for the caller to bank as debt.
pub(crate) fn charge_transfer(
    cost: &Option<TransferCost>,
    w: usize,
    r: &Request,
    ctx: &mut SimCtx,
) -> f64 {
    let tokens = r.input_len as u64;
    let stall = cost.as_ref().map(|c| c.stall(tokens)).unwrap_or(0.0);
    ctx.record_kv_transfer(w, tokens, stall);
    stall
}

/// Largest outstanding transfer debt among `reqs`, removed from the map.
/// Transfers overlap, so a batch stalls until its slowest member's KV
/// lands — the max, not the sum. 0 when no member owes anything (the
/// fault-free fast path: the map is empty).
pub(crate) fn take_debt(debt: &mut BTreeMap<u64, f64>, reqs: &[Request]) -> f64 {
    if debt.is_empty() {
        return 0.0;
    }
    let mut stall = 0.0f64;
    for r in reqs {
        if let Some(d) = debt.remove(&r.id) {
            stall = stall.max(d);
        }
    }
    stall
}

/// Apply a slice outcome at its completion boundary: charge each request
/// its pads and a pass, apply token effects (the SCLS reschedule prefill
/// recomputes over input + generated), stamp finish times. `now` is the
/// completion event's timestamp — bit-identical to the `done_at` computed
/// at serving start, because the event time IS that f64.
pub(crate) fn settle_batch(slot: ServingSlot, now: f64) -> Batch {
    let ServingSlot {
        mut batch,
        outcome,
        li,
    } = slot;
    for (r, o) in batch.requests.iter_mut().zip(&outcome.per_request) {
        debug_assert_eq!(r.id, o.id);
        r.slices += 1;
        r.pad_tokens += (li - r.input_len) as u64;
        // First-token stamp for TTFT accounting: this boundary emitted the
        // request's first generated token.
        if r.generated == 0 && o.new_tokens > 0 {
            r.first_token_at = Some(now);
        }
        r.generated += o.new_tokens;
        r.invalid_tokens += o.invalid_tokens as u64;
        // SCLS reschedule: the next prefill recomputes over input +
        // everything generated so far.
        r.input_len += o.new_tokens;
        if o.finished {
            r.finished_at = Some(now);
        }
    }
    batch
}

// ---------------------------------------------------------------------------
// Sliced family (SLS / SO / PM / AB / LB / SCLS)
// ---------------------------------------------------------------------------

/// Per-worker state for the sliced-family policy.
struct WorkerState {
    /// Coordinator-formed batches waiting in the local queue.
    batch_queue: VecDeque<Batch>,
    /// Worker-locus FCFS: raw requests waiting locally (SLS/SO).
    req_queue: VecDeque<Request>,
    /// The batch + pending outcome currently in flight (None = idle).
    serving: Option<ServingSlot>,
    engine: SimEngine,
    last_done: f64,
}

impl WorkerState {
    /// A cold worker under (fresh, never-reused) index `w`: the engine
    /// seed stream is decorrelated per index exactly like the initial
    /// fleet's.
    fn cold(preset: &EnginePreset, seed: u64, max_gen_len: u32, w: usize) -> WorkerState {
        WorkerState {
            batch_queue: VecDeque::new(),
            req_queue: VecDeque::new(),
            serving: None,
            engine: SimEngine::new(
                preset.latency(seed ^ (w as u64).wrapping_mul(0x9E37)),
                max_gen_len,
            ),
            last_done: 0.0,
        }
    }
}

/// Static-batching sliced-family scheduler: any `SchedulerSpec` point
/// (slice length × batching × offload × interval) over simulated workers.
pub struct SlicedPolicy {
    coord: SlicedCoordinator,
    est: ServingTimeEstimator,
    mem: MemoryEstimator,
    workers: Vec<WorkerState>,
    /// Engine preset + base seed + generation cap, kept to build joiners'
    /// engines mid-run.
    preset: EnginePreset,
    seed: u64,
    max_gen_len: u32,
    /// Whether a tick event is currently scheduled (ticked specs only) —
    /// joins re-arm a tick that died while the whole fleet was down.
    tick_armed: bool,
    /// Scratch for draining the coordinator's parked requests on a join.
    park_buf: Vec<Request>,
    /// KV-transfer cost model for migrations (`None` = free, pre-PR 10).
    kv_transfer: Option<TransferCost>,
    /// Outstanding per-request transfer stalls, paid at serving start.
    transfer_debt: BTreeMap<u64, f64>,
}

impl SlicedPolicy {
    /// Build the policy the way the SCLS deployment starts up (§4.2):
    /// profile the engine's latency model once, fit Eq. (3)/(4), then
    /// instantiate per-worker engines on decorrelated seed streams.
    pub fn new(spec: &SchedulerSpec, cfg: &SimConfig) -> SlicedPolicy {
        assert!(cfg.workers > 0);
        let est = fitted_estimator(&cfg.engine, cfg.seed);
        let mem = cfg.engine.memory_estimator();
        let workers: Vec<WorkerState> = (0..cfg.workers)
            .map(|w| WorkerState::cold(&cfg.engine, cfg.seed, cfg.max_gen_len, w))
            .collect();
        // `pred_corrected_dp` is deliberately NOT forwarded here: plain
        // sliced policies never stamp `predicted_gen`, so the correction
        // would change nothing semantically while trading the optimized
        // DP planner for the scalar corrected loop. Prediction-aware
        // callers that share this coordinator (the real-mode driver, or a
        // custom policy stamping predictions before `admit`) opt in via
        // `SlicedCoordinator::set_pred_correction`.
        let mut coord = SlicedCoordinator::new(spec, cfg.workers);
        // `None` weights leave the coordinator on the exact legacy drain
        // path (byte-identical); `Some` switches `schedule_tick` to
        // deficit-weighted per-tenant admission.
        coord.set_tenant_weights(cfg.tenant_weights.clone());
        SlicedPolicy {
            coord,
            est,
            mem,
            workers,
            preset: cfg.engine.clone(),
            seed: cfg.seed,
            max_gen_len: cfg.max_gen_len,
            tick_armed: false,
            park_buf: Vec::new(),
            kv_transfer: cfg.kv_transfer,
            transfer_debt: BTreeMap::new(),
        }
    }

    /// Start serving on worker `w` if idle and work is queued.
    fn try_start(&mut self, w: usize, ctx: &mut SimCtx) {
        let slice_len = self.coord.spec().slice_len;
        let batching = self.coord.spec().batching.clone();
        let ws = &mut self.workers[w];
        if ws.serving.is_some() {
            return;
        }
        // Worker-locus FCFS: form a batch from the local request queue.
        if let BatchingSpec::WorkerFcfs { batch_size } = batching {
            if ws.batch_queue.is_empty() && !ws.req_queue.is_empty() {
                let take = (batch_size as usize).min(ws.req_queue.len());
                let reqs: Vec<Request> = ws.req_queue.drain(..take).collect();
                let mut batches = fcfs_batches(reqs, batch_size, &self.est, slice_len);
                debug_assert_eq!(batches.len(), 1);
                ws.batch_queue.push_back(batches.pop().unwrap());
            }
        }
        let Some(batch) = ws.batch_queue.pop_front() else {
            return;
        };
        let size = batch.size();
        let stall = take_debt(&mut self.transfer_debt, &batch.requests);
        start_static_batch(&mut ws.engine, &mut ws.serving, w, batch, slice_len, stall, ctx);
        self.coord.note_batch_start(w, size, ctx.now);
    }

    /// Route a reclaimed/migrated/parked request back through the
    /// coordinator (pooled specs pick it up at the next tick).
    fn readmit(&mut self, r: Request, ctx: &mut SimCtx) {
        if let Some((tw, r)) = self.coord.admit(r) {
            self.workers[tw].req_queue.push_back(r);
            self.try_start(tw, ctx);
        }
    }

    /// Re-arm a stopped tick: joins and reclaims can create work while no
    /// tick is scheduled (the loop parks once the whole fleet is down
    /// instead of ticking forever).
    fn ensure_tick(&mut self, ctx: &mut SimCtx) {
        if self.coord.has_ticks() && !self.tick_armed {
            ctx.tick_at(ctx.now);
            self.tick_armed = true;
        }
    }
}

impl SchedulingPolicy for SlicedPolicy {
    fn init(&mut self, ctx: &mut SimCtx) {
        self.coord.reserve_pool(ctx.arrivals_left().min(1 << 16));
        if self.coord.has_ticks() {
            ctx.tick_at(0.0);
            self.tick_armed = true;
        }
    }

    fn on_arrival(&mut self, req: Request, ctx: &mut SimCtx) {
        // SLS/SO: round-robin to a worker queue; otherwise pooled.
        if let Some((w, r)) = self.coord.admit(req) {
            self.workers[w].req_queue.push_back(r);
            self.try_start(w, ctx);
        }
    }

    fn on_tick(&mut self, ctx: &mut SimCtx) {
        if !self.coord.has_ticks() {
            return;
        }
        self.tick_armed = false;
        let drained = self.coord.schedule_tick(&self.est, &self.mem);
        if drained > 0 {
            ctx.observe_pool(drained);
            let mut assign = self.coord.take_assignments();
            for (w, b) in assign.drain(..) {
                self.workers[w].batch_queue.push_back(b);
                self.try_start(w, ctx);
            }
            self.coord.recycle_assignments(assign);
        }
        // Re-arm the tick while any work can still appear AND the fleet
        // can still move it (no accepting worker and nothing serving =
        // park until a joiner re-arms; ticking would spin forever).
        let work_pending = ctx.arrivals_left() > 0
            || !self.coord.pool_is_empty()
            || self
                .workers
                .iter()
                .any(|w| w.serving.is_some() || !w.batch_queue.is_empty());
        let can_progress = self.coord.fleet().accepting_count() > 0
            || self.workers.iter().any(|w| w.serving.is_some());
        if work_pending && can_progress {
            let t = self
                .coord
                .next_interval()
                .expect("on_tick only fires for ticked policies");
            ctx.tick_at(ctx.now + t.max(1e-3));
            self.tick_armed = true;
        }
    }

    fn on_worker_done(&mut self, w: usize, ctx: &mut SimCtx) {
        // A completion racing a crash: the slot was already reclaimed.
        let Some(slot) = self.workers[w].serving.take() else {
            return;
        };
        let new_tokens = slot.new_tokens_total();
        let batch = settle_batch(slot, ctx.now);
        self.coord.batch_done(w, batch.est_serve_time);
        self.coord.note_progress(w, ctx.now);
        self.workers[w].last_done = ctx.now;
        // Telemetry sample at the slice boundary (static batching releases
        // the batch here, so KV-in-use is 0 by construction).
        let depth = self.workers[w].batch_queue.len() + self.workers[w].req_queue.len();
        ctx.record_served(w, new_tokens, 0, depth);
        for r in batch.requests {
            if r.is_finished() {
                ctx.record_completion(&r);
            } else if let Some((tw, r)) = self.coord.admit(r) {
                // SO: re-send unfinished requests round-robin.
                self.workers[tw].req_queue.push_back(r);
                self.try_start(tw, ctx);
            }
        }
        if self.coord.is_draining(w) {
            // Queued work migrated when the drain landed; this boundary
            // retires the worker.
            self.coord.worker_retired(w);
            return;
        }
        self.try_start(w, ctx);
    }

    fn on_worker_join(&mut self, w: usize, ctx: &mut SimCtx) {
        debug_assert_eq!(w, self.workers.len(), "join indices are dense");
        self.workers
            .push(WorkerState::cold(&self.preset, self.seed, self.max_gen_len, w));
        let registered = self.coord.worker_join(ctx.now);
        debug_assert_eq!(registered, w);
        ctx.record_fleet(FleetRecord {
            worker: w,
            kind: FleetEventKind::Join,
        });
        // Worker-locus specs park arrivals while nothing accepts: hand the
        // backlog to the restored fleet. Pooled specs keep the backlog in
        // the pool; the re-armed tick below drains it.
        if !self.coord.has_ticks() {
            let mut parked = std::mem::take(&mut self.park_buf);
            self.coord.take_parked(&mut parked);
            for r in parked.drain(..) {
                self.readmit(r, ctx);
            }
            self.park_buf = parked;
        }
        self.ensure_tick(ctx);
    }

    fn on_worker_lost(&mut self, w: usize, loss: WorkerLoss, ctx: &mut SimCtx) {
        match loss {
            WorkerLoss::Drain => {
                if self.coord.fleet().health(w) != WorkerHealth::Alive {
                    return;
                }
                self.coord.worker_drain(w);
                ctx.record_fleet(FleetRecord {
                    worker: w,
                    kind: FleetEventKind::Drain,
                });
                // Migrate everything not yet started — queued batches and
                // raw requests all sit at a slice boundary by construction
                // — and release their charged load.
                let ws = &mut self.workers[w];
                let mut moved: Vec<Request> = Vec::new();
                let mut freed = 0.0;
                for b in ws.batch_queue.drain(..) {
                    freed += b.est_serve_time;
                    moved.extend(b.requests);
                }
                moved.extend(ws.req_queue.drain(..));
                if freed > 0.0 {
                    self.coord.batch_done(w, freed);
                }
                if !moved.is_empty() {
                    ctx.record_migration(w, moved.len());
                    for r in moved {
                        let stall = charge_transfer(&self.kv_transfer, w, &r, ctx);
                        if stall > 0.0 {
                            self.transfer_debt.insert(r.id, stall);
                        }
                        self.readmit(r, ctx);
                    }
                }
                if self.workers[w].serving.is_none() {
                    self.coord.worker_retired(w);
                }
                self.ensure_tick(ctx);
            }
            WorkerLoss::Crash => {
                if self.coord.fleet().health(w) == WorkerHealth::Dead {
                    return;
                }
                self.coord.worker_crash(w);
                ctx.record_fleet(FleetRecord {
                    worker: w,
                    kind: FleetEventKind::Crash,
                });
                // Reclaim: dropping the serving slot's unapplied outcome
                // recovers its requests in their exact last-boundary state
                // (≤ one slice of work lost); queued work never started.
                let ws = &mut self.workers[w];
                let mut in_flight = 0usize;
                let mut reclaimed: Vec<Request> = Vec::new();
                if let Some(slot) = ws.serving.take() {
                    in_flight = slot.batch.size();
                    reclaimed.extend(slot.batch.requests);
                }
                for b in ws.batch_queue.drain(..) {
                    reclaimed.extend(b.requests);
                }
                reclaimed.extend(ws.req_queue.drain(..));
                let queued = reclaimed.len() - in_flight;
                if in_flight + queued > 0 {
                    ctx.record_reclaim(w, in_flight, queued);
                }
                // The queued portion migrates (its context ships to a new
                // worker); the in-flight portion re-prefills from the last
                // boundary — a recompute, not a transfer.
                for (i, r) in reclaimed.into_iter().enumerate() {
                    if i >= in_flight {
                        let stall = charge_transfer(&self.kv_transfer, w, &r, ctx);
                        if stall > 0.0 {
                            self.transfer_debt.insert(r.id, stall);
                        }
                    }
                    self.readmit(r, ctx);
                }
                self.ensure_tick(ctx);
            }
        }
    }

    fn on_coordinator_crash(&mut self, ctx: &mut SimCtx) {
        // Successor takeover: each worker reports its authoritative state
        // (the DES reads the report off the worker structs and the fleet
        // mirror, which tracks exactly what a worker knows about itself —
        // its health, in-flight batch, progress cursor, and the estimated
        // serve-time of everything it holds).
        let reports: Vec<WorkerReport> = self
            .workers
            .iter()
            .enumerate()
            .map(|(w, ws)| {
                let mut charged = 0.0;
                let mut in_flight = 0;
                if let Some(slot) = &ws.serving {
                    in_flight = slot.batch.size();
                    charged += slot.batch.est_serve_time;
                }
                for b in &ws.batch_queue {
                    charged += b.est_serve_time;
                }
                WorkerReport {
                    worker: w,
                    health: self.coord.fleet().health(w),
                    in_flight,
                    progress: self.coord.fleet().last_progress(w),
                    charged_load: charged,
                }
            })
            .collect();
        // Requests no worker holds — the dead coordinator's pool — are
        // recovered from the arrival log (the DES hands the lost pool
        // contents straight back; a real deployment replays its journal).
        let mut recovered = std::mem::take(&mut self.park_buf);
        self.coord.take_parked(&mut recovered);
        self.coord.rebuild_after_crash(ctx.now, &reports, &mut recovered);
        self.park_buf = recovered;
        self.ensure_tick(ctx);
    }

    fn finish(&mut self, metrics: &mut RunMetrics) {
        metrics.worker_completion = self.workers.iter().map(|w| w.last_done).collect();
    }
}

// ---------------------------------------------------------------------------
// ILS: iteration-level scheduling with continuous batching (FastGen-like)
// ---------------------------------------------------------------------------

/// The ILS baseline: per-iteration joins and exits, no padding, no invalid
/// tokens — but a conservative cap on parallel requests plus a KV-memory
/// admission check (§1, §5.1). Requests are offloaded round-robin, as the
/// paper's baselines do (§3.2).
pub struct IlsPolicy {
    workers: Vec<ContinuousWorker>,
    looping: Vec<bool>,
    last_done: Vec<f64>,
    health: Vec<WorkerHealth>,
    /// Requests with nowhere to go (whole fleet down) until a joiner.
    parked: VecDeque<Request>,
    rr: RoundRobin,
    kv_budget: u64,
    max_kv_seen: u64,
    /// Engine preset + base seed + generation cap for building joiners.
    preset: EnginePreset,
    seed: u64,
    max_gen_len: u32,
    /// KV-transfer cost model for migrations (`None` = free, pre-PR 10).
    kv_transfer: Option<TransferCost>,
    /// Outstanding per-request transfer stalls (parked requests keep
    /// theirs until routed).
    transfer_debt: BTreeMap<u64, f64>,
    /// Per-worker stall folded into its next iteration arm.
    pending_stall: Vec<f64>,
}

impl IlsPolicy {
    pub fn new(cfg: &SimConfig) -> IlsPolicy {
        assert!(cfg.workers > 0);
        let kv_budget = (0.9 * cfg.engine.m_ava as f64) as u64;
        let workers: Vec<ContinuousWorker> = (0..cfg.workers)
            .map(|w| {
                ContinuousWorker::new(
                    cfg.engine
                        .latency(cfg.seed ^ (w as u64).wrapping_mul(0xA5A5)),
                    cfg.engine.ils_max_parallel,
                    kv_budget,
                    cfg.engine.kv_delta,
                    cfg.max_gen_len,
                )
            })
            .collect();
        let n = workers.len();
        IlsPolicy {
            workers,
            looping: vec![false; n],
            last_done: vec![0.0; n],
            health: vec![WorkerHealth::Alive; n],
            parked: VecDeque::new(),
            rr: RoundRobin::new(n),
            kv_budget,
            max_kv_seen: 0,
            preset: cfg.engine.clone(),
            seed: cfg.seed,
            max_gen_len: cfg.max_gen_len,
            kv_transfer: cfg.kv_transfer,
            transfer_debt: BTreeMap::new(),
            pending_stall: vec![0.0; n],
        }
    }

    /// Per-instance KV budget the admission check enforces.
    pub fn kv_budget(&self) -> u64 {
        self.kv_budget
    }

    /// Largest KV-in-use observed on any instance (no-OOM invariant:
    /// never exceeds [`Self::kv_budget`]).
    pub fn max_kv_observed(&self) -> u64 {
        self.max_kv_seen
    }

    /// Schedule `w`'s next iteration completion, folding in any pending
    /// KV-transfer stall (0 on fault-free runs — bit-identical arming).
    fn arm(&mut self, w: usize, d: f64, ctx: &mut SimCtx) {
        let stall = std::mem::take(&mut self.pending_stall[w]);
        if stall > 0.0 {
            ctx.complete_at(ctx.now + stall + d, w);
        } else {
            ctx.complete_at(ctx.now + d, w);
        }
    }

    /// Kick worker `w`'s iteration loop if it is idle.
    fn kick(&mut self, w: usize, ctx: &mut SimCtx) {
        if !self.looping[w] {
            if let Some(d) = self.workers[w].begin_iteration() {
                self.looping[w] = true;
                self.max_kv_seen = self.max_kv_seen.max(self.workers[w].kv_in_use());
                self.arm(w, d, ctx);
            }
        }
    }

    /// Next alive worker in round-robin order, or `None` if the whole
    /// fleet is down/draining. On a fixed fleet the first probe is alive,
    /// so the cursor advances exactly as pre-elastic.
    fn route(&mut self) -> Option<usize> {
        for _ in 0..self.rr.workers() {
            let w = self.rr.next_worker();
            if self.health[w] == WorkerHealth::Alive {
                return Some(w);
            }
        }
        None
    }

    /// Route to an alive worker or park until one joins. A routed
    /// request's outstanding transfer debt folds into the target's next
    /// iteration arm; a parked request keeps its debt mapped.
    fn reroute(&mut self, req: Request, ctx: &mut SimCtx) {
        match self.route() {
            Some(w) => {
                if !self.transfer_debt.is_empty() {
                    if let Some(d) = self.transfer_debt.remove(&req.id) {
                        self.pending_stall[w] = self.pending_stall[w].max(d);
                    }
                }
                self.workers[w].waiting.push_back(req);
                self.kick(w, ctx);
            }
            None => self.parked.push_back(req),
        }
    }
}

impl SchedulingPolicy for IlsPolicy {
    fn on_arrival(&mut self, req: Request, ctx: &mut SimCtx) {
        self.reroute(req, ctx);
    }

    fn on_worker_done(&mut self, wi: usize, ctx: &mut SimCtx) {
        if self.health[wi] == WorkerHealth::Dead {
            return; // stale completion from a crashed worker
        }
        let done = self.workers[wi].finish_iteration(ctx.now);
        // Every request running this iteration decoded one token: the
        // exits plus whatever is still running.
        let new_tokens = (done.len() + self.workers[wi].running.len()) as u64;
        let kv = self.workers[wi].kv_in_use();
        ctx.record_served(wi, new_tokens, kv, self.workers[wi].waiting.len());
        for r in done {
            self.last_done[wi] = ctx.now;
            ctx.record_completion(&r);
        }
        if let Some(d) = self.workers[wi].begin_iteration() {
            self.max_kv_seen = self.max_kv_seen.max(self.workers[wi].kv_in_use());
            self.arm(wi, d, ctx);
        } else {
            self.looping[wi] = false;
            if self.health[wi] == WorkerHealth::Draining {
                // Drained dry — retired for good.
                self.health[wi] = WorkerHealth::Dead;
            }
        }
    }

    fn on_worker_join(&mut self, w: usize, ctx: &mut SimCtx) {
        debug_assert_eq!(w, self.workers.len(), "join indices are dense");
        self.workers.push(ContinuousWorker::new(
            self.preset
                .latency(self.seed ^ (w as u64).wrapping_mul(0xA5A5)),
            self.preset.ils_max_parallel,
            self.kv_budget,
            self.preset.kv_delta,
            self.max_gen_len,
        ));
        self.looping.push(false);
        self.last_done.push(0.0);
        self.health.push(WorkerHealth::Alive);
        self.pending_stall.push(0.0);
        self.rr.grow(self.workers.len());
        ctx.record_fleet(FleetRecord {
            worker: w,
            kind: FleetEventKind::Join,
        });
        while let Some(r) = self.parked.pop_front() {
            let t = self.route().expect("a worker just joined");
            self.workers[t].waiting.push_back(r);
            self.kick(t, ctx);
        }
    }

    fn on_worker_lost(&mut self, w: usize, loss: WorkerLoss, ctx: &mut SimCtx) {
        match loss {
            WorkerLoss::Drain => {
                if self.health[w] != WorkerHealth::Alive {
                    return;
                }
                self.health[w] = WorkerHealth::Draining;
                ctx.record_fleet(FleetRecord {
                    worker: w,
                    kind: FleetEventKind::Drain,
                });
                // ILS admits at iteration boundaries: the waiting queue
                // never started, so it migrates wholesale; the running set
                // finishes in place.
                let moved: Vec<Request> = self.workers[w].waiting.drain(..).collect();
                if !moved.is_empty() {
                    ctx.record_migration(w, moved.len());
                    for r in moved {
                        let stall = charge_transfer(&self.kv_transfer, w, &r, ctx);
                        if stall > 0.0 {
                            self.transfer_debt.insert(r.id, stall);
                        }
                        self.reroute(r, ctx);
                    }
                }
                if !self.looping[w] {
                    self.health[w] = WorkerHealth::Dead; // idle — retired now
                }
            }
            WorkerLoss::Crash => {
                if self.health[w] == WorkerHealth::Dead {
                    return;
                }
                self.health[w] = WorkerHealth::Dead;
                self.looping[w] = false;
                ctx.record_fleet(FleetRecord {
                    worker: w,
                    kind: FleetEventKind::Crash,
                });
                let (running, waiting) = self.workers[w].abandon();
                if running.len() + waiting.len() > 0 {
                    ctx.record_reclaim(w, running.len(), waiting.len());
                }
                for mut r in running {
                    // Recovered at the last completed iteration boundary;
                    // the re-prefill covers everything generated so far (a
                    // recompute, not a KV transfer — nothing to charge).
                    r.input_len = r.orig_input_len + r.generated;
                    self.reroute(r, ctx);
                }
                for r in waiting {
                    // Queued work moves instances: its resident KV (the
                    // prefillable context) pays the transfer toll.
                    let stall = charge_transfer(&self.kv_transfer, w, &r, ctx);
                    if stall > 0.0 {
                        self.transfer_debt.insert(r.id, stall);
                    }
                    self.reroute(r, ctx);
                }
            }
        }
    }

    fn finish(&mut self, metrics: &mut RunMetrics) {
        metrics.worker_completion = self.last_done.clone();
    }
}

// ---------------------------------------------------------------------------
// SCLS-CB: slice-level scheduling over continuous batching (paper §7)
// ---------------------------------------------------------------------------

/// The §7 extension: continuous batching per instance (no pads, no invalid
/// tokens), each schedule capped at `slice_len` generated tokens,
/// **precise** per-slice memory admission instead of ILS's conservative
/// cap, and coordinator-side offloading of new and rescheduled requests to
/// the instance with the most free projected KV memory.
pub struct SclsCbPolicy {
    workers: Vec<SlicedContinuousWorker>,
    looping: Vec<bool>,
    last_done: Vec<f64>,
    health: Vec<WorkerHealth>,
    /// Requests with nowhere to go (whole fleet down) until a joiner.
    parked: VecDeque<Request>,
    kv_budget: u64,
    max_kv_seen: u64,
    /// Engine preset + base seed + caps for building joiners.
    preset: EnginePreset,
    seed: u64,
    slice_len: u32,
    max_gen_len: u32,
    /// KV-transfer cost model for migrations (`None` = free, pre-PR 10).
    kv_transfer: Option<TransferCost>,
    /// Outstanding per-request transfer stalls (parked requests keep
    /// theirs until routed).
    transfer_debt: BTreeMap<u64, f64>,
    /// Per-worker stall folded into its next iteration arm.
    pending_stall: Vec<f64>,
}

impl SclsCbPolicy {
    pub fn new(cfg: &SimConfig, slice_len: u32) -> SclsCbPolicy {
        assert!(cfg.workers > 0);
        let kv_budget = (0.9 * cfg.engine.m_ava as f64) as u64;
        let workers: Vec<SlicedContinuousWorker> = (0..cfg.workers)
            .map(|w| {
                SlicedContinuousWorker::new(
                    cfg.engine
                        .latency(cfg.seed ^ (w as u64).wrapping_mul(0x5A5A)),
                    slice_len,
                    kv_budget,
                    cfg.engine.kv_delta,
                    cfg.max_gen_len,
                )
            })
            .collect();
        let n = workers.len();
        SclsCbPolicy {
            workers,
            looping: vec![false; n],
            last_done: vec![0.0; n],
            health: vec![WorkerHealth::Alive; n],
            parked: VecDeque::new(),
            kv_budget,
            max_kv_seen: 0,
            preset: cfg.engine.clone(),
            seed: cfg.seed,
            slice_len,
            max_gen_len: cfg.max_gen_len,
            kv_transfer: cfg.kv_transfer,
            transfer_debt: BTreeMap::new(),
            pending_stall: vec![0.0; n],
        }
    }

    /// Per-instance KV budget the precise admission enforces.
    pub fn kv_budget(&self) -> u64 {
        self.kv_budget
    }

    /// Largest *projected* KV observed on any instance after admission
    /// (no-OOM invariant: never exceeds [`Self::kv_budget`]).
    pub fn max_kv_observed(&self) -> u64 {
        self.max_kv_seen
    }

    /// Schedule `w`'s next iteration completion, folding in any pending
    /// KV-transfer stall (0 on fault-free runs — bit-identical arming).
    fn arm(&mut self, w: usize, d: f64, ctx: &mut SimCtx) {
        let stall = std::mem::take(&mut self.pending_stall[w]);
        if stall > 0.0 {
            ctx.complete_at(ctx.now + stall + d, w);
        } else {
            ctx.complete_at(ctx.now + d, w);
        }
    }

    /// Offload to the alive instance with the most free projected memory
    /// (ties: shortest local queue); kick its iteration loop if idle. With
    /// the whole fleet down/draining, park until a joiner. On a fixed
    /// all-alive fleet the filter keeps the iteration order, so the argmin
    /// — and the run — is bit-identical to pre-elastic.
    fn assign(&mut self, r: Request, ctx: &mut SimCtx) {
        let pick = (0..self.workers.len())
            .filter(|&w| self.health[w] == WorkerHealth::Alive)
            .min_by(|&a, &b| {
                self.workers[a]
                    .kv_projected()
                    .cmp(&self.workers[b].kv_projected())
                    .then_with(|| {
                        self.workers[a]
                            .waiting
                            .len()
                            .cmp(&self.workers[b].waiting.len())
                    })
            });
        let w = match pick {
            Some(w) => w,
            None => {
                self.parked.push_back(r);
                return;
            }
        };
        if !self.transfer_debt.is_empty() {
            if let Some(d) = self.transfer_debt.remove(&r.id) {
                self.pending_stall[w] = self.pending_stall[w].max(d);
            }
        }
        self.workers[w].waiting.push_back(r);
        if !self.looping[w] {
            if let Some(d) = self.workers[w].begin_iteration() {
                self.looping[w] = true;
                self.max_kv_seen = self.max_kv_seen.max(self.workers[w].kv_projected());
                self.arm(w, d, ctx);
            }
        }
    }
}

impl SchedulingPolicy for SclsCbPolicy {
    fn on_arrival(&mut self, req: Request, ctx: &mut SimCtx) {
        self.assign(req, ctx);
    }

    fn on_worker_done(&mut self, wi: usize, ctx: &mut SimCtx) {
        if self.health[wi] == WorkerHealth::Dead {
            return; // stale completion from a crashed worker
        }
        let exits = self.workers[wi].finish_iteration(ctx.now);
        // Every request running this iteration decoded one token: the
        // exits plus whatever is still running.
        let new_tokens =
            (exits.done.len() + exits.rescheduled.len() + self.workers[wi].running_len()) as u64;
        let kv = self.workers[wi].kv_projected();
        ctx.record_served(wi, new_tokens, kv, self.workers[wi].waiting.len());
        for r in exits.done {
            self.last_done[wi] = ctx.now;
            ctx.record_completion(&r);
        }
        // §7: slice-capped requests are rescheduled to the least
        // memory-loaded instance (their KV was just released; the fresh
        // prefill on the target already models the recompute, so no
        // transfer toll here).
        for r in exits.rescheduled {
            self.assign(r, ctx);
        }
        if let Some(d) = self.workers[wi].begin_iteration() {
            self.max_kv_seen = self.max_kv_seen.max(self.workers[wi].kv_projected());
            self.arm(wi, d, ctx);
        } else {
            self.looping[wi] = false;
            if self.health[wi] == WorkerHealth::Draining {
                // Drained dry — retired for good.
                self.health[wi] = WorkerHealth::Dead;
            }
        }
    }

    fn on_worker_join(&mut self, w: usize, ctx: &mut SimCtx) {
        debug_assert_eq!(w, self.workers.len(), "join indices are dense");
        self.workers.push(SlicedContinuousWorker::new(
            self.preset
                .latency(self.seed ^ (w as u64).wrapping_mul(0x5A5A)),
            self.slice_len,
            self.kv_budget,
            self.preset.kv_delta,
            self.max_gen_len,
        ));
        self.looping.push(false);
        self.last_done.push(0.0);
        self.health.push(WorkerHealth::Alive);
        self.pending_stall.push(0.0);
        ctx.record_fleet(FleetRecord {
            worker: w,
            kind: FleetEventKind::Join,
        });
        while let Some(r) = self.parked.pop_front() {
            self.assign(r, ctx);
        }
    }

    fn on_worker_lost(&mut self, w: usize, loss: WorkerLoss, ctx: &mut SimCtx) {
        match loss {
            WorkerLoss::Drain => {
                if self.health[w] != WorkerHealth::Alive {
                    return;
                }
                self.health[w] = WorkerHealth::Draining;
                ctx.record_fleet(FleetRecord {
                    worker: w,
                    kind: FleetEventKind::Drain,
                });
                // The waiting queue never started: it migrates wholesale
                // and pays the transfer toll; the running set finishes its
                // slices in place (slice exits re-assign elsewhere since
                // `assign` skips non-alive instances).
                let moved: Vec<Request> = self.workers[w].waiting.drain(..).collect();
                if !moved.is_empty() {
                    ctx.record_migration(w, moved.len());
                    for r in moved {
                        let stall = charge_transfer(&self.kv_transfer, w, &r, ctx);
                        if stall > 0.0 {
                            self.transfer_debt.insert(r.id, stall);
                        }
                        self.assign(r, ctx);
                    }
                }
                if !self.looping[w] {
                    self.health[w] = WorkerHealth::Dead; // idle — retired now
                }
            }
            WorkerLoss::Crash => {
                if self.health[w] == WorkerHealth::Dead {
                    return;
                }
                self.health[w] = WorkerHealth::Dead;
                self.looping[w] = false;
                self.pending_stall[w] = 0.0;
                ctx.record_fleet(FleetRecord {
                    worker: w,
                    kind: FleetEventKind::Crash,
                });
                let (running, waiting) = self.workers[w].abandon();
                if running.len() + waiting.len() > 0 {
                    ctx.record_reclaim(w, running.len(), waiting.len());
                }
                for mut r in running {
                    // Recovered at the last completed iteration boundary;
                    // the re-prefill covers everything generated so far (a
                    // recompute, not a KV transfer — nothing to charge).
                    r.input_len = r.orig_input_len + r.generated;
                    self.assign(r, ctx);
                }
                for r in waiting {
                    // Queued work moves instances: its resident KV (the
                    // prefillable context) pays the transfer toll.
                    let stall = charge_transfer(&self.kv_transfer, w, &r, ctx);
                    if stall > 0.0 {
                        self.transfer_debt.insert(r.id, stall);
                    }
                    self.assign(r, ctx);
                }
            }
        }
    }

    fn finish(&mut self, metrics: &mut RunMetrics) {
        metrics.worker_completion = self.last_done.clone();
    }
}

// ---------------------------------------------------------------------------
// P-SCLS: prediction-seeded slice-level scheduling (static batching)
// ---------------------------------------------------------------------------

/// Per-worker state for P-SCLS: coordinator-formed batches carry the
/// iteration budget of the rung they were cut for.
struct PredWorkerState {
    /// (iteration budget, batch) pairs waiting in the local queue.
    batch_queue: VecDeque<(u32, Batch)>,
    /// The batch + pending outcome currently in flight (None = idle).
    serving: Option<ServingSlot>,
    engine: SimEngine,
    last_done: f64,
}

impl PredWorkerState {
    /// A cold worker under (fresh, never-reused) index `w`, on the P-SCLS
    /// seed stream.
    fn cold(preset: &EnginePreset, seed: u64, max_gen_len: u32, w: usize) -> PredWorkerState {
        PredWorkerState {
            batch_queue: VecDeque::new(),
            serving: None,
            engine: SimEngine::new(
                preset.latency(seed ^ (w as u64).wrapping_mul(0x7A3D)),
                max_gen_len,
            ),
            last_done: 0.0,
        }
    }
}

/// **P-SCLS** — SCLS with prediction-seeded ladder entry.
///
/// Baseline SCLS serves every request S tokens per schedule: a request
/// that generates `k·S` tokens climbs the ladder in `k` passes, paying a
/// full re-prefill (input + generated so far) at each rung. P-SCLS asks a
/// [`LengthPredictor`] once at arrival and seeds the request at the rung
/// matching its predicted bucket: its *first* schedule gets an iteration
/// budget of `k·S` (k = ⌈pred/S⌉), so an accurately predicted request
/// completes in one pass with one prefill. Requests are pooled per rung;
/// each tick runs the Alg. 1 DP batcher *within* each rung (so co-batched
/// requests share both input-length affinity and iteration budget) and
/// offloads all rung batches together via the spec's offload axis.
///
/// Mispredict recovery:
/// * **under-prediction** — a request unfinished after its seeded pass is
///   re-queued to the next rung: one more pass of S (vanilla SCLS
///   behaviour from there on), counted in `RunMetrics::underpredicted`;
/// * **over-prediction** — a completion whose reserved rungs exceed
///   ⌈generated/S⌉ logs the unused rungs as `wasted_kv_token_steps`
///   (rung-granular: `(reserved − needed)·S` token-slots).
///
/// Every completion is also fed back through
/// [`LengthPredictor::observe`], so an online predictor
/// ([`crate::predictor::OnlineBuckets`]) refits its buckets from the
/// traffic it actually served. With `SimConfig::pred_corrected_dp` the
/// per-rung DP additionally costs batches at their *predicted* budget
/// instead of the rung's worst case (see [`crate::batcher::dp`]), so the
/// load ledger and LPT offload see estimates that anticipate early
/// returns. The corrected planner is a running-max-aware branch-and-bound
/// over the bulk estimator kernels — on par with the legacy optimized
/// path — so the correction no longer costs P-SCLS its tick budget at
/// large pools.
///
/// With the [`crate::predictor::Oracle`] predictor every request completes
/// in exactly one pass, which is never more passes than baseline SCLS —
/// the invariant `props_predictor.rs` checks on fixed seeds.
pub struct PredictiveSlicedPolicy {
    spec: SchedulerSpec,
    predictor: Box<dyn LengthPredictor>,
    est: ServingTimeEstimator,
    mem: MemoryEstimator,
    ledger: LoadLedger,
    rr: RoundRobin,
    /// Worker-lifecycle ledger (health, heartbeats, in-flight ownership).
    fleet: WorkerLedger,
    interval: IntervalController,
    /// One pool per rung: `pools[b-1]` holds requests whose next pass gets
    /// an iteration budget of `b·S` (requeues always land on rung 1).
    pools: Vec<RequestPool>,
    workers: Vec<PredWorkerState>,
    /// Engine preset + base seed for building joiners mid-run.
    preset: EnginePreset,
    seed: u64,
    max_gen_len: u32,
    max_rung: u32,
    /// Whether a tick event is currently scheduled — joins re-arm a tick
    /// that died while the whole fleet was down.
    tick_armed: bool,
    /// Cost rung batches at their predicted budget (`SimConfig::pred_corrected_dp`).
    pred_corrected: bool,
    /// KV-transfer cost model for migrations (`None` = free, pre-PR 10).
    kv_transfer: Option<TransferCost>,
    /// Outstanding per-request transfer stalls (pooled requests keep
    /// theirs until their next batch starts).
    transfer_debt: BTreeMap<u64, f64>,
    // Reused per-tick buffers (allocation-lean discipline from PR 1).
    tick_reqs: Vec<Request>,
    batch_buf: Vec<Batch>,
    staged: Vec<(u32, Batch)>,
    assign_buf: Vec<(usize, u32, Batch)>,
    dp_scratch: DpScratch,
}

impl PredictiveSlicedPolicy {
    pub fn new(
        spec: &SchedulerSpec,
        cfg: &SimConfig,
        predictor: Box<dyn LengthPredictor>,
    ) -> PredictiveSlicedPolicy {
        assert!(cfg.workers > 0);
        let s = spec.slice_len.max(1);
        let max_rung = ((cfg.max_gen_len + s - 1) / s).max(1);
        let est = fitted_estimator(&cfg.engine, cfg.seed);
        let mem = cfg.engine.memory_estimator();
        let workers: Vec<PredWorkerState> = (0..cfg.workers)
            .map(|w| PredWorkerState::cold(&cfg.engine, cfg.seed, cfg.max_gen_len, w))
            .collect();
        let interval = match spec.interval {
            IntervalSpec::Fixed(t) => IntervalController::Fixed(t),
            IntervalSpec::Adaptive { lambda, gamma } => {
                IntervalController::Adaptive { lambda, gamma }
            }
            // P-SCLS is inherently ticked: pooling per rung needs a tick.
            IntervalSpec::Immediate => IntervalController::Fixed(cfg.engine.gamma),
        };
        PredictiveSlicedPolicy {
            spec: spec.clone(),
            predictor,
            est,
            mem,
            ledger: LoadLedger::new(cfg.workers),
            rr: RoundRobin::new(cfg.workers),
            fleet: WorkerLedger::new(cfg.workers),
            interval,
            pools: (0..max_rung).map(|_| RequestPool::new()).collect(),
            workers,
            preset: cfg.engine.clone(),
            seed: cfg.seed,
            max_gen_len: cfg.max_gen_len,
            max_rung,
            tick_armed: false,
            pred_corrected: cfg.pred_corrected_dp,
            kv_transfer: cfg.kv_transfer,
            transfer_debt: BTreeMap::new(),
            tick_reqs: Vec::new(),
            batch_buf: Vec::new(),
            staged: Vec::new(),
            assign_buf: Vec::new(),
            dp_scratch: DpScratch::new(),
        }
    }

    /// Ladder rung for a predicted total generation length.
    fn rung_of(&self, predicted: u32) -> u32 {
        let s = self.spec.slice_len.max(1);
        let eff = predicted.min(self.max_gen_len).max(1);
        ((eff + s - 1) / s).clamp(1, self.max_rung)
    }

    /// Iteration budget of rung `b` (the whole ladder up to the rung).
    fn rung_budget(&self, b: u32) -> u32 {
        (b * self.spec.slice_len).min(self.max_gen_len).max(1)
    }

    fn pooled(&self) -> usize {
        self.pools.iter().map(|p| p.len()).sum()
    }

    /// Start serving on worker `w` if idle and work is queued.
    fn try_start(&mut self, w: usize, ctx: &mut SimCtx) {
        if self.workers[w].serving.is_some() {
            return;
        }
        let Some((budget, batch)) = self.workers[w].batch_queue.pop_front() else {
            return;
        };
        let size = batch.size();
        let stall = take_debt(&mut self.transfer_debt, &batch.requests);
        let ws = &mut self.workers[w];
        start_static_batch(&mut ws.engine, &mut ws.serving, w, batch, budget, stall, ctx);
        self.fleet.batch_started(w, size, ctx.now);
    }

    /// Re-queue a reclaimed request at the rung matching what it still
    /// owes (its prediction minus what survived the reclaim) — a crashed
    /// pass costs at most its interrupted slice, not a restart from rung 1.
    fn requeue_reclaimed(&mut self, r: Request) {
        let owed = r
            .predicted_gen
            .unwrap_or(1)
            .saturating_sub(r.generated)
            .max(1);
        let rung = self.rung_of(owed);
        self.pools[rung as usize - 1].push(r);
    }

    /// Re-arm a stopped tick (joins and reclaims can create work while no
    /// tick is scheduled — the loop parks once the whole fleet is down).
    fn ensure_tick(&mut self, ctx: &mut SimCtx) {
        if !self.tick_armed {
            ctx.tick_at(ctx.now);
            self.tick_armed = true;
        }
    }
}

impl SchedulingPolicy for PredictiveSlicedPolicy {
    fn init(&mut self, ctx: &mut SimCtx) {
        self.pools[0].reserve(ctx.arrivals_left().min(1 << 16));
        ctx.tick_at(0.0);
        self.tick_armed = true;
    }

    fn on_arrival(&mut self, mut req: Request, _ctx: &mut SimCtx) {
        // Pooled until the next tick; the seeded rung is the prediction's.
        let pred = self.predictor.predict(&req).max(1);
        req.predicted_gen = Some(pred);
        let rung = self.rung_of(pred);
        self.pools[rung as usize - 1].push(req);
    }

    fn on_tick(&mut self, ctx: &mut SimCtx) {
        self.tick_armed = false;
        let drained = self.pooled();
        if drained > 0 {
            ctx.observe_pool(drained);
            // DP-batch each rung with the rung's iteration budget, then
            // offload everything together.
            for b in 1..=self.max_rung {
                if self.pools[b as usize - 1].is_empty() {
                    continue;
                }
                let budget = self.rung_budget(b);
                self.pools[b as usize - 1].drain_sorted_into(&mut self.tick_reqs);
                let dp_cfg = DpBatcherConfig {
                    slice_len: budget,
                    max_batch_size: match self.spec.batching {
                        BatchingSpec::Dp { max_batch_size } => max_batch_size,
                        BatchingSpec::WorkerFcfs { batch_size } => Some(batch_size),
                    },
                    pred_corrected: self.pred_corrected,
                };
                dp_batch_sorted_into(
                    &mut self.tick_reqs,
                    &self.est,
                    &self.mem,
                    &dp_cfg,
                    &mut self.dp_scratch,
                    &mut self.batch_buf,
                );
                // Correction accounting: the batcher counted how many
                // batches it costed strictly below the rung's slice cap.
                for _ in 0..self.dp_scratch.corrected_batches() {
                    ctx.record_corrected_batch();
                }
                self.staged
                    .extend(self.batch_buf.drain(..).map(|batch| (budget, batch)));
            }
            // Unplaceable batches (whole fleet down mid-fault) dissolve
            // back to their rung's pool until a joiner re-arms the tick.
            let s = self.spec.slice_len.max(1);
            let max_rung = self.max_rung;
            let rung_idx = |budget: u32| (((budget + s - 1) / s).clamp(1, max_rung) - 1) as usize;
            match self.spec.offload {
                OffloadSpec::MaxMin => {
                    // LPT over all rung batches: longest estimate first to
                    // the least-loaded accepting worker (paper §4.5).
                    self.staged
                        .sort_by(|a, b| b.1.est_serve_time.total_cmp(&a.1.est_serve_time));
                    for (budget, batch) in self.staged.drain(..) {
                        match self.ledger.try_argmin() {
                            Some(w) => {
                                self.ledger.add(w, batch.est_serve_time);
                                self.assign_buf.push((w, budget, batch));
                            }
                            None => {
                                let b = rung_idx(budget);
                                for r in batch.requests {
                                    self.pools[b].push(r);
                                }
                            }
                        }
                    }
                }
                OffloadSpec::RoundRobin => {
                    for (budget, batch) in self.staged.drain(..) {
                        let mut placed = None;
                        for _ in 0..self.rr.workers() {
                            let w = self.rr.next_worker();
                            if self.ledger.is_accepting(w) {
                                placed = Some(w);
                                break;
                            }
                        }
                        match placed {
                            Some(w) => {
                                self.ledger.add(w, batch.est_serve_time);
                                self.assign_buf.push((w, budget, batch));
                            }
                            None => {
                                let b = rung_idx(budget);
                                for r in batch.requests {
                                    self.pools[b].push(r);
                                }
                            }
                        }
                    }
                }
            }
            let mut assign = std::mem::take(&mut self.assign_buf);
            for (w, budget, batch) in assign.drain(..) {
                self.workers[w].batch_queue.push_back((budget, batch));
                self.try_start(w, ctx);
            }
            self.assign_buf = assign;
        }
        // Re-arm the tick while any work can still appear AND the fleet
        // can still move it (park otherwise; a joiner re-arms).
        let work_pending = ctx.arrivals_left() > 0
            || self.pooled() > 0
            || self
                .workers
                .iter()
                .any(|w| w.serving.is_some() || !w.batch_queue.is_empty());
        let can_progress = self.ledger.accepting_count() > 0
            || self.workers.iter().any(|w| w.serving.is_some());
        if work_pending && can_progress {
            let t = self.interval.next_interval(&self.ledger);
            ctx.tick_at(ctx.now + t.max(1e-3));
            self.tick_armed = true;
        }
    }

    fn on_worker_done(&mut self, w: usize, ctx: &mut SimCtx) {
        // A completion racing a crash: the slot was already reclaimed.
        let Some(slot) = self.workers[w].serving.take() else {
            return;
        };
        let new_tokens = slot.new_tokens_total();
        let batch = settle_batch(slot, ctx.now);
        self.ledger.complete(w, batch.est_serve_time);
        self.fleet.batch_completed(w, ctx.now);
        self.workers[w].last_done = ctx.now;
        // Telemetry sample at the slice boundary (static batching releases
        // the batch here, so KV-in-use is 0 by construction).
        ctx.record_served(w, new_tokens, 0, self.workers[w].batch_queue.len());
        let s = self.spec.slice_len.max(1);
        for r in batch.requests {
            if r.is_finished() {
                // Completion feedback: online predictors refit from the
                // true generated length.
                if self.predictor.observe(&r, r.generated) {
                    ctx.record_refit();
                }
                // Over-prediction accounting, rung-granular: rungs reserved
                // (seeded rung + one per extra pass) vs rungs needed.
                let k0 = self.rung_of(r.predicted_gen.unwrap_or(1)) as u64;
                let reserved = k0 + (r.slices.max(1) as u64 - 1);
                let needed = ((r.generated.max(1) + s - 1) / s) as u64;
                if reserved > needed {
                    ctx.record_prediction(PredictionRecord {
                        id: r.id,
                        underpredicted: false,
                        wasted_tokens: (reserved - needed) * s as u64,
                    });
                }
                ctx.record_completion(&r);
            } else {
                // Under-prediction: re-queue to the next rung (one more
                // pass of S from here on).
                ctx.record_prediction(PredictionRecord {
                    id: r.id,
                    underpredicted: true,
                    wasted_tokens: 0,
                });
                self.pools[0].push(r);
            }
        }
        if self.fleet.health(w) == WorkerHealth::Draining && self.workers[w].batch_queue.is_empty()
        {
            // Queued batches migrated when the drain landed; this boundary
            // retires the worker.
            self.fleet.set_health(w, WorkerHealth::Dead);
            return;
        }
        self.try_start(w, ctx);
    }

    fn on_worker_join(&mut self, w: usize, ctx: &mut SimCtx) {
        debug_assert_eq!(w, self.workers.len(), "join indices are dense");
        self.workers
            .push(PredWorkerState::cold(&self.preset, self.seed, self.max_gen_len, w));
        let lw = self.ledger.add_worker();
        let fw = self.fleet.add_worker(ctx.now);
        debug_assert_eq!(lw, w);
        debug_assert_eq!(fw, w);
        self.rr.grow(self.workers.len());
        ctx.record_fleet(FleetRecord {
            worker: w,
            kind: FleetEventKind::Join,
        });
        self.ensure_tick(ctx);
    }

    fn on_worker_lost(&mut self, w: usize, loss: WorkerLoss, ctx: &mut SimCtx) {
        match loss {
            WorkerLoss::Drain => {
                if self.fleet.health(w) != WorkerHealth::Alive {
                    return;
                }
                self.fleet.set_health(w, WorkerHealth::Draining);
                self.ledger.set_accepting(w, false);
                ctx.record_fleet(FleetRecord {
                    worker: w,
                    kind: FleetEventKind::Drain,
                });
                // Migrate queued (unstarted) batches back to their rung
                // pools and release their charged load; the in-flight
                // slice finishes in place.
                let queue: Vec<(u32, Batch)> = self.workers[w].batch_queue.drain(..).collect();
                let mut moved = 0usize;
                for (budget, batch) in queue {
                    self.ledger.complete(w, batch.est_serve_time);
                    moved += batch.size();
                    let rung = self.rung_of(budget) as usize - 1;
                    for r in batch.requests {
                        // Queued work moves instances: its resident KV
                        // pays the transfer toll, banked until the request
                        // starts on its next worker.
                        let stall = charge_transfer(&self.kv_transfer, w, &r, ctx);
                        if stall > 0.0 {
                            self.transfer_debt.insert(r.id, stall);
                        }
                        self.pools[rung].push(r);
                    }
                }
                if moved > 0 {
                    ctx.record_migration(w, moved);
                }
                if self.workers[w].serving.is_none() {
                    self.fleet.set_health(w, WorkerHealth::Dead);
                }
                self.ensure_tick(ctx);
            }
            WorkerLoss::Crash => {
                if self.fleet.health(w) == WorkerHealth::Dead {
                    return;
                }
                self.fleet.set_health(w, WorkerHealth::Dead);
                self.fleet.clear_in_flight(w);
                self.ledger.set_accepting(w, false);
                self.ledger.reset(w);
                ctx.record_fleet(FleetRecord {
                    worker: w,
                    kind: FleetEventKind::Crash,
                });
                // Dropping the slot's unapplied outcome recovers the
                // serving requests at their last boundary; each re-enters
                // the ladder at the rung it still owes.
                let mut in_flight = 0usize;
                if let Some(slot) = self.workers[w].serving.take() {
                    in_flight = slot.batch.size();
                    for r in slot.batch.requests {
                        self.requeue_reclaimed(r);
                    }
                }
                let queue: Vec<(u32, Batch)> = self.workers[w].batch_queue.drain(..).collect();
                let mut queued = 0usize;
                for (_, batch) in queue {
                    queued += batch.size();
                    for r in batch.requests {
                        // Queued work migrates (the in-flight slot above
                        // re-prefills instead — a recompute, not a
                        // transfer) and pays the KV toll.
                        let stall = charge_transfer(&self.kv_transfer, w, &r, ctx);
                        if stall > 0.0 {
                            self.transfer_debt.insert(r.id, stall);
                        }
                        self.requeue_reclaimed(r);
                    }
                }
                if in_flight + queued > 0 {
                    ctx.record_reclaim(w, in_flight, queued);
                }
                self.ensure_tick(ctx);
            }
        }
    }

    fn on_coordinator_crash(&mut self, ctx: &mut SimCtx) {
        // The coordinator's soft state (load ledger, RR cursor, worker
        // mirror) is lost; the successor reconstructs it from
        // authoritative worker-side reports: health, in-flight batch, last
        // progress boundary, and the serving + queued load each worker
        // still owes. Charged load equals the pre-crash ledger entry
        // exactly — the ledger charges per assignment and releases per
        // batch completion, both of which the worker can replay.
        let reports: Vec<WorkerReport> = (0..self.workers.len())
            .map(|w| {
                let ws = &self.workers[w];
                let mut charged = 0.0f64;
                let mut in_flight = 0usize;
                if let Some(slot) = &ws.serving {
                    in_flight = slot.batch.size();
                    charged += slot.batch.est_serve_time;
                }
                for (_, batch) in &ws.batch_queue {
                    charged += batch.est_serve_time;
                }
                WorkerReport {
                    worker: w,
                    health: self.fleet.health(w),
                    in_flight,
                    progress: self.fleet.last_progress(w),
                    charged_load: charged,
                }
            })
            .collect();
        self.ledger = LoadLedger::new(reports.len());
        self.rr = RoundRobin::new(reports.len());
        self.fleet = WorkerLedger::from_reports(ctx.now, &reports);
        for rep in &reports {
            if rep.health != WorkerHealth::Alive {
                self.ledger.set_accepting(rep.worker, false);
            }
            if rep.charged_load > 0.0 {
                self.ledger.add(rep.worker, rep.charged_load);
            }
        }
        // Rung pools survive as the recovery set itself: pooled requests
        // are exactly the unassigned arrivals the log would replay, and
        // keeping them in place preserves their prediction stamps (an
        // online predictor re-stamping could differ).
        self.ensure_tick(ctx);
    }

    fn finish(&mut self, metrics: &mut RunMetrics) {
        metrics.worker_completion = self.workers.iter().map(|w| w.last_done).collect();
    }
}

// ---------------------------------------------------------------------------
// P-CB: continuous batching with predicted-KV admission
// ---------------------------------------------------------------------------

/// **P-CB** — continuous batching that admits against *predicted* KV
/// demand instead of the worst-case `max_gen_len` reservation.
///
/// Each request is stamped with a [`LengthPredictor`] estimate at arrival
/// and placed on the instance with the most free *reserved* memory; the
/// instance admits it iff its predicted remaining generation fits
/// alongside the reservations already running
/// ([`PredictiveContinuousWorker`]). Recovery: under-predicted requests
/// are evicted at the boundary where their reservation runs out and
/// re-admitted with a doubled prediction (so recoveries per request are
/// logarithmic), paying a fresh prefill like an SCLS-CB slice exit;
/// over-predicted completions log their unused reservation. The KV-budget
/// invariant therefore holds under arbitrary prediction error — the
/// property `props_predictor.rs` hammers across randomized error draws.
/// Every completion is fed back through [`LengthPredictor::observe`], so
/// an online predictor refits its reservation model from served traffic.
pub struct PredictiveCbPolicy {
    workers: Vec<PredictiveContinuousWorker>,
    looping: Vec<bool>,
    last_done: Vec<f64>,
    health: Vec<WorkerHealth>,
    /// Requests with nowhere to go (whole fleet down) until a joiner.
    parked: VecDeque<Request>,
    predictor: Box<dyn LengthPredictor>,
    max_gen_len: u32,
    kv_budget: u64,
    max_kv_seen: u64,
    /// Engine preset + base seed for building joiners mid-run.
    preset: EnginePreset,
    seed: u64,
    /// KV-transfer cost model for migrations (`None` = free, pre-PR 10).
    kv_transfer: Option<TransferCost>,
    /// Outstanding per-request transfer stalls (parked requests keep
    /// theirs until routed).
    transfer_debt: BTreeMap<u64, f64>,
    /// Per-worker stall folded into its next iteration arm.
    pending_stall: Vec<f64>,
}

impl PredictiveCbPolicy {
    pub fn new(cfg: &SimConfig, predictor: Box<dyn LengthPredictor>) -> PredictiveCbPolicy {
        assert!(cfg.workers > 0);
        let kv_budget = (0.9 * cfg.engine.m_ava as f64) as u64;
        let workers: Vec<PredictiveContinuousWorker> = (0..cfg.workers)
            .map(|w| {
                PredictiveContinuousWorker::new(
                    cfg.engine
                        .latency(cfg.seed ^ (w as u64).wrapping_mul(0xD1CE)),
                    kv_budget,
                    cfg.engine.kv_delta,
                    cfg.max_gen_len,
                )
            })
            .collect();
        let n = workers.len();
        PredictiveCbPolicy {
            workers,
            looping: vec![false; n],
            last_done: vec![0.0; n],
            health: vec![WorkerHealth::Alive; n],
            parked: VecDeque::new(),
            predictor,
            max_gen_len: cfg.max_gen_len,
            kv_budget,
            max_kv_seen: 0,
            preset: cfg.engine.clone(),
            seed: cfg.seed,
            kv_transfer: cfg.kv_transfer,
            transfer_debt: BTreeMap::new(),
            pending_stall: vec![0.0; n],
        }
    }

    /// Per-instance KV budget the predicted admission enforces.
    pub fn kv_budget(&self) -> u64 {
        self.kv_budget
    }

    /// Largest *projected* (reservation-sum) KV observed on any instance
    /// after admission — the no-OOM invariant bounds actual use by it, and
    /// it never exceeds [`Self::kv_budget`].
    pub fn max_kv_observed(&self) -> u64 {
        self.max_kv_seen
    }

    /// Schedule `w`'s next iteration completion, folding in any pending
    /// KV-transfer stall (0 on fault-free runs — bit-identical arming).
    fn arm(&mut self, w: usize, d: f64, ctx: &mut SimCtx) {
        let stall = std::mem::take(&mut self.pending_stall[w]);
        if stall > 0.0 {
            ctx.complete_at(ctx.now + stall + d, w);
        } else {
            ctx.complete_at(ctx.now + d, w);
        }
    }

    /// Offload to the alive instance with the most free reserved memory
    /// (ties: shortest local queue); kick its iteration loop if idle. With
    /// the whole fleet down/draining, park until a joiner. On a fixed
    /// all-alive fleet the filter keeps the iteration order, so the argmin
    /// — and the run — is bit-identical to pre-elastic.
    fn assign(&mut self, r: Request, ctx: &mut SimCtx) {
        let pick = (0..self.workers.len())
            .filter(|&w| self.health[w] == WorkerHealth::Alive)
            .min_by(|&a, &b| {
                self.workers[a]
                    .kv_projected()
                    .cmp(&self.workers[b].kv_projected())
                    .then_with(|| {
                        self.workers[a]
                            .waiting
                            .len()
                            .cmp(&self.workers[b].waiting.len())
                    })
            });
        let w = match pick {
            Some(w) => w,
            None => {
                self.parked.push_back(r);
                return;
            }
        };
        if !self.transfer_debt.is_empty() {
            if let Some(d) = self.transfer_debt.remove(&r.id) {
                self.pending_stall[w] = self.pending_stall[w].max(d);
            }
        }
        self.workers[w].waiting.push_back(r);
        if !self.looping[w] {
            if let Some(d) = self.workers[w].begin_iteration() {
                self.looping[w] = true;
                self.max_kv_seen = self.max_kv_seen.max(self.workers[w].kv_projected());
                self.arm(w, d, ctx);
            }
        }
    }
}

impl SchedulingPolicy for PredictiveCbPolicy {
    fn on_arrival(&mut self, mut req: Request, ctx: &mut SimCtx) {
        req.predicted_gen = Some(self.predictor.predict(&req).max(1));
        self.assign(req, ctx);
    }

    fn on_worker_done(&mut self, wi: usize, ctx: &mut SimCtx) {
        if self.health[wi] == WorkerHealth::Dead {
            return; // stale completion from a crashed worker
        }
        let exits = self.workers[wi].finish_iteration(ctx.now);
        // Every request running this iteration decoded one token: the
        // exits plus whatever is still running.
        let new_tokens =
            (exits.done.len() + exits.evicted.len() + self.workers[wi].running_len()) as u64;
        let kv = self.workers[wi].kv_projected();
        ctx.record_served(wi, new_tokens, kv, self.workers[wi].waiting.len());
        for (r, unused) in exits.done {
            self.last_done[wi] = ctx.now;
            // Completion feedback: online predictors refit from the true
            // generated length.
            if self.predictor.observe(&r, r.generated) {
                ctx.record_refit();
            }
            if unused > 0 {
                ctx.record_prediction(PredictionRecord {
                    id: r.id,
                    underpredicted: false,
                    wasted_tokens: unused as u64,
                });
            }
            ctx.record_completion(&r);
        }
        // Mispredict recovery: evicted requests re-enter with a doubled
        // prediction (capped at the generation limit), so each request is
        // re-admitted at most O(log max_gen_len) times.
        for mut r in exits.evicted {
            ctx.record_prediction(PredictionRecord {
                id: r.id,
                underpredicted: true,
                wasted_tokens: 0,
            });
            let old = r.predicted_gen.unwrap_or(self.max_gen_len);
            let bumped = old
                .max(r.generated)
                .saturating_mul(2)
                .min(self.max_gen_len.max(r.generated + 1));
            r.predicted_gen = Some(bumped);
            self.assign(r, ctx);
        }
        if let Some(d) = self.workers[wi].begin_iteration() {
            self.max_kv_seen = self.max_kv_seen.max(self.workers[wi].kv_projected());
            self.arm(wi, d, ctx);
        } else {
            self.looping[wi] = false;
            if self.health[wi] == WorkerHealth::Draining {
                // Drained dry — retired for good.
                self.health[wi] = WorkerHealth::Dead;
            }
        }
    }

    fn on_worker_join(&mut self, w: usize, ctx: &mut SimCtx) {
        debug_assert_eq!(w, self.workers.len(), "join indices are dense");
        self.workers.push(PredictiveContinuousWorker::new(
            self.preset
                .latency(self.seed ^ (w as u64).wrapping_mul(0xD1CE)),
            self.kv_budget,
            self.preset.kv_delta,
            self.max_gen_len,
        ));
        self.looping.push(false);
        self.last_done.push(0.0);
        self.health.push(WorkerHealth::Alive);
        self.pending_stall.push(0.0);
        ctx.record_fleet(FleetRecord {
            worker: w,
            kind: FleetEventKind::Join,
        });
        while let Some(r) = self.parked.pop_front() {
            self.assign(r, ctx);
        }
    }

    fn on_worker_lost(&mut self, w: usize, loss: WorkerLoss, ctx: &mut SimCtx) {
        match loss {
            WorkerLoss::Drain => {
                if self.health[w] != WorkerHealth::Alive {
                    return;
                }
                self.health[w] = WorkerHealth::Draining;
                ctx.record_fleet(FleetRecord {
                    worker: w,
                    kind: FleetEventKind::Drain,
                });
                // The waiting queue never started: it migrates wholesale
                // and pays the transfer toll; the running set finishes (or
                // evicts at reservation exhaustion) in place — `assign`
                // skips non-alive instances, so exits land elsewhere.
                let moved: Vec<Request> = self.workers[w].waiting.drain(..).collect();
                if !moved.is_empty() {
                    ctx.record_migration(w, moved.len());
                    for r in moved {
                        let stall = charge_transfer(&self.kv_transfer, w, &r, ctx);
                        if stall > 0.0 {
                            self.transfer_debt.insert(r.id, stall);
                        }
                        self.assign(r, ctx);
                    }
                }
                if !self.looping[w] {
                    self.health[w] = WorkerHealth::Dead; // idle — retired now
                }
            }
            WorkerLoss::Crash => {
                if self.health[w] == WorkerHealth::Dead {
                    return;
                }
                self.health[w] = WorkerHealth::Dead;
                self.looping[w] = false;
                self.pending_stall[w] = 0.0;
                ctx.record_fleet(FleetRecord {
                    worker: w,
                    kind: FleetEventKind::Crash,
                });
                let (running, waiting) = self.workers[w].abandon();
                if running.len() + waiting.len() > 0 {
                    ctx.record_reclaim(w, running.len(), waiting.len());
                }
                for mut r in running {
                    // Recovered at the last completed iteration boundary;
                    // the re-prefill covers everything generated so far (a
                    // recompute, not a KV transfer — nothing to charge).
                    // The stale `predicted_gen` is kept: `reservation()`
                    // clamps the remaining reservation to ≥ 1, so a
                    // too-small stamp costs at most one short residency
                    // before the evict/double ladder re-calibrates.
                    r.input_len = r.orig_input_len + r.generated;
                    self.assign(r, ctx);
                }
                for r in waiting {
                    // Queued work moves instances: its resident KV (the
                    // prefillable context) pays the transfer toll.
                    let stall = charge_transfer(&self.kv_transfer, w, &r, ctx);
                    if stall > 0.0 {
                        self.transfer_debt.insert(r.id, stall);
                    }
                    self.assign(r, ctx);
                }
            }
        }
    }

    fn finish(&mut self, metrics: &mut RunMetrics) {
        metrics.worker_completion = self.last_done.clone();
    }
}
