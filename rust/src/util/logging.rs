//! Minimal `log` facade backend (env_logger is not in the offline registry).
//!
//! `SCLS_LOG=debug|info|warn|error|off` controls the level (default `info`).
//! Messages go to stderr with elapsed wall-time prefixes.

use std::sync::OnceLock;
use std::time::Instant;

struct StderrLogger {
    start: Instant,
    level: log::LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, m: &log::Metadata) -> bool {
        m.level() <= self.level
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        let t = self.start.elapsed().as_secs_f64();
        eprintln!(
            "[{t:9.3}s {:5} {}] {}",
            record.level(),
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

static LOGGER: OnceLock<StderrLogger> = OnceLock::new();

/// Install the logger (idempotent). Call once at binary startup.
pub fn init() {
    let level = match std::env::var("SCLS_LOG").as_deref() {
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("error") => log::LevelFilter::Error,
        Ok("off") => log::LevelFilter::Off,
        _ => log::LevelFilter::Info,
    };
    let logger = LOGGER.get_or_init(|| StderrLogger {
        start: Instant::now(),
        level,
    });
    let _ = log::set_logger(logger);
    log::set_max_level(level);
}
