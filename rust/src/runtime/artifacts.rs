//! Artifact discovery: parse `artifacts/manifest.json` (written by
//! `python -m compile.aot`) and map a concrete (batch, padded-length) onto
//! the nearest exported bucket.

use std::path::{Path, PathBuf};

use anyhow::{anyhow, Context, Result};

use crate::util::json::Json;

/// One exported (N, L, S) bucket.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Bucket {
    pub n: u32,
    pub l: u32,
    pub s: u32,
    pub file: String,
}

/// Model metadata baked into the artifacts.
#[derive(Debug, Clone)]
pub struct ModelInfo {
    pub vocab: u32,
    pub d_model: u32,
    pub n_heads: u32,
    pub n_layers: u32,
    pub max_pos: u32,
    pub kv_bytes_per_token: u64,
    pub pad_id: i32,
    pub eos_id: i32,
    pub bos_id: i32,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub model: ModelInfo,
    pub buckets: Vec<Bucket>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;

        let geti = |path: &[&str]| -> Result<i64> {
            j.at(path)
                .and_then(Json::as_i64)
                .ok_or_else(|| anyhow!("manifest: missing {}", path.join(".")))
        };
        let model = ModelInfo {
            vocab: geti(&["model", "vocab"])? as u32,
            d_model: geti(&["model", "d_model"])? as u32,
            n_heads: geti(&["model", "n_heads"])? as u32,
            n_layers: geti(&["model", "n_layers"])? as u32,
            max_pos: geti(&["model", "max_pos"])? as u32,
            kv_bytes_per_token: geti(&["model", "kv_bytes_per_token"])? as u64,
            pad_id: geti(&["tokens", "pad"])? as i32,
            eos_id: geti(&["tokens", "eos"])? as i32,
            bos_id: geti(&["tokens", "bos"])? as i32,
        };

        let mut buckets = Vec::new();
        for b in j
            .get("buckets")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest: missing buckets"))?
        {
            let get = |k: &str| -> Result<i64> {
                b.get(k)
                    .and_then(Json::as_i64)
                    .ok_or_else(|| anyhow!("bucket: missing {k}"))
            };
            buckets.push(Bucket {
                n: get("n")? as u32,
                l: get("l")? as u32,
                s: get("s")? as u32,
                file: b
                    .get("file")
                    .and_then(Json::as_str)
                    .ok_or_else(|| anyhow!("bucket: missing file"))?
                    .to_string(),
            });
        }
        if buckets.is_empty() {
            return Err(anyhow!("manifest has no buckets"));
        }
        buckets.sort_by_key(|b| (b.s, b.l, b.n));
        Ok(Manifest {
            dir: dir.to_path_buf(),
            model,
            buckets,
        })
    }

    /// Slice lengths available in the artifact set.
    pub fn slice_lens(&self) -> Vec<u32> {
        let mut v: Vec<u32> = self.buckets.iter().map(|b| b.s).collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Smallest bucket with bucket.n ≥ n, bucket.l ≥ l, bucket.s == s.
    pub fn pick(&self, n: u32, l: u32, s: u32) -> Option<&Bucket> {
        self.buckets
            .iter()
            .filter(|b| b.s == s && b.n >= n && b.l >= l)
            .min_by_key(|b| (b.l, b.n))
    }

    /// Largest batch size servable at padded length `l` with slice `s` —
    /// the real engine's bucket-capacity constraint (feeds the memory
    /// estimator's table rule).
    pub fn max_batch_for(&self, l: u32, s: u32) -> Option<u32> {
        self.buckets
            .iter()
            .filter(|b| b.s == s && b.l >= l)
            .map(|b| b.n)
            .max()
    }

    pub fn bucket_path(&self, b: &Bucket) -> PathBuf {
        self.dir.join(&b.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn art_dir() -> PathBuf {
        // CARGO_MANIFEST_DIR = repo root (workspace layout keeps rust/ inside)
        Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        art_dir().join("manifest.json").exists()
    }

    #[test]
    fn loads_real_manifest() {
        if !have_artifacts() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&art_dir()).unwrap();
        assert_eq!(m.model.pad_id, 0);
        assert_eq!(m.model.eos_id, 1);
        assert!(m.model.kv_bytes_per_token > 0);
        assert!(!m.buckets.is_empty());
        for b in &m.buckets {
            assert!(m.bucket_path(b).exists(), "missing {:?}", b.file);
        }
    }

    #[test]
    fn pick_rounds_up() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(&art_dir()).unwrap();
        let s = m.slice_lens()[0];
        // exact hit
        let b = m.pick(1, 16, s).unwrap();
        assert_eq!((b.n, b.l), (1, 16));
        // round up both dims
        let b = m.pick(3, 17, s).unwrap();
        assert!(b.n >= 3 && b.l >= 17);
        assert_eq!(b.n, 4, "smallest n-bucket >= 3");
        assert_eq!(b.l, 32, "smallest l-bucket >= 17");
        // unsatisfiable
        assert!(m.pick(1000, 16, s).is_none());
        assert!(m.pick(1, 100_000, s).is_none());
    }

    #[test]
    fn max_batch_for_caps() {
        if !have_artifacts() {
            return;
        }
        let m = Manifest::load(&art_dir()).unwrap();
        let s = m.slice_lens()[0];
        assert_eq!(m.max_batch_for(16, s), Some(8));
        assert_eq!(m.max_batch_for(100_000, s), None);
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Manifest::load(Path::new("/nonexistent-dir-xyz")).is_err());
    }
}
