// Lint fixture (never compiled): a deterministic-module file with zero
// findings under every rule — the negative control for tests/props_lint.rs.
use std::collections::BTreeMap;
use std::collections::BTreeSet;

fn clean(xs: &mut [f64], m: &BTreeMap<u64, u64>, s: &BTreeSet<u64>) -> u64 {
    xs.sort_by(|a, b| a.total_cmp(b));
    let virtual_now = 12.5_f64;
    let count = m.len() as u64 + s.len() as u64;
    if virtual_now.total_cmp(&0.0).is_eq() {
        return 0;
    }
    count
}
