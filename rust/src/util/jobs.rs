//! Std-only scoped-thread job pool (the offline registry has no rayon).
//!
//! [`parallel_map`] fans independent work items out over N worker threads
//! and returns results **in input order**, so callers that assemble output
//! sequentially from the results are byte-identical to a sequential run —
//! the property the figure suite's `--jobs` flag relies on.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Map `f` over `items` on up to `jobs` scoped threads, preserving input
/// order in the returned vector. `jobs <= 1` (or a single item) runs
/// inline with no threads spawned, guaranteeing the parallel and
/// sequential paths produce identical results for deterministic `f`.
///
/// Work is claimed from a shared atomic cursor (dynamic load balancing:
/// simulation cells vary widely in cost).
pub fn parallel_map<T, R, F>(jobs: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(T) -> R + Sync,
{
    let n = items.len();
    if jobs <= 1 || n <= 1 {
        return items.into_iter().map(f).collect();
    }
    let work: Vec<Mutex<Option<T>>> = items.into_iter().map(|t| Mutex::new(Some(t))).collect();
    let results: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
    let cursor = AtomicUsize::new(0);
    let f = &f;
    let work = &work;
    let results = &results;
    let cursor = &cursor;
    std::thread::scope(|scope| {
        for _ in 0..jobs.min(n) {
            scope.spawn(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let item = work[i].lock().unwrap().take().expect("item claimed twice");
                let r = f(item);
                *results[i].lock().unwrap() = Some(r);
            });
        }
    });
    results
        .iter()
        .map(|m| m.lock().unwrap().take().expect("worker died before finishing"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(4, items, |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn sequential_and_parallel_agree() {
        let items: Vec<u64> = (0..37).collect();
        let seq = parallel_map(1, items.clone(), |x| x.wrapping_mul(0x9E37).rotate_left(7));
        let par = parallel_map(8, items, |x| x.wrapping_mul(0x9E37).rotate_left(7));
        assert_eq!(seq, par);
    }

    #[test]
    fn more_jobs_than_items() {
        let out = parallel_map(16, vec![1, 2, 3], |x| x + 1);
        assert_eq!(out, vec![2, 3, 4]);
    }

    #[test]
    fn empty_and_single() {
        let empty: Vec<u32> = Vec::new();
        assert!(parallel_map(4, empty, |x| x).is_empty());
        assert_eq!(parallel_map(4, vec![9], |x| x * x), vec![81]);
    }
}
