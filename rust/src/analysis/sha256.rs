//! Minimal std-only SHA-256 (FIPS 180-4) for the frozen-artifact manifest.
//!
//! The offline registry has no hashing crate; this is the textbook
//! compression function, verified against the FIPS test vectors in the
//! unit tests below. Only `digest_hex` is exposed — the manifest deals in
//! lowercase hex digests, the same format `sha256sum` prints.

const K: [u32; 64] = [
    0x428a2f98, 0x71374491, 0xb5c0fbcf, 0xe9b5dba5, 0x3956c25b, 0x59f111f1, 0x923f82a4, 0xab1c5ed5,
    0xd807aa98, 0x12835b01, 0x243185be, 0x550c7dc3, 0x72be5d74, 0x80deb1fe, 0x9bdc06a7, 0xc19bf174,
    0xe49b69c1, 0xefbe4786, 0x0fc19dc6, 0x240ca1cc, 0x2de92c6f, 0x4a7484aa, 0x5cb0a9dc, 0x76f988da,
    0x983e5152, 0xa831c66d, 0xb00327c8, 0xbf597fc7, 0xc6e00bf3, 0xd5a79147, 0x06ca6351, 0x14292967,
    0x27b70a85, 0x2e1b2138, 0x4d2c6dfc, 0x53380d13, 0x650a7354, 0x766a0abb, 0x81c2c92e, 0x92722c85,
    0xa2bfe8a1, 0xa81a664b, 0xc24b8b70, 0xc76c51a3, 0xd192e819, 0xd6990624, 0xf40e3585, 0x106aa070,
    0x19a4c116, 0x1e376c08, 0x2748774c, 0x34b0bcb5, 0x391c0cb3, 0x4ed8aa4a, 0x5b9cca4f, 0x682e6ff3,
    0x748f82ee, 0x78a5636f, 0x84c87814, 0x8cc70208, 0x90befffa, 0xa4506ceb, 0xbef9a3f7, 0xc67178f2,
];

const H0: [u32; 8] = [
    0x6a09e667, 0xbb67ae85, 0x3c6ef372, 0xa54ff53a, 0x510e527f, 0x9b05688c, 0x1f83d9ab, 0x5be0cd19,
];

/// SHA-256 of `data` as lowercase hex.
pub fn digest_hex(data: &[u8]) -> String {
    let mut h = H0;
    let bit_len = (data.len() as u64).wrapping_mul(8);

    // Padded message: data || 0x80 || zeros || 64-bit big-endian length,
    // to a multiple of 64 bytes.
    let mut msg = Vec::with_capacity(data.len() + 72);
    msg.extend_from_slice(data);
    msg.push(0x80);
    while msg.len() % 64 != 56 {
        msg.push(0);
    }
    msg.extend_from_slice(&bit_len.to_be_bytes());

    let mut w = [0u32; 64];
    for block in msg.chunks_exact(64) {
        for (t, wt) in w.iter_mut().take(16).enumerate() {
            *wt = u32::from_be_bytes([
                block[4 * t],
                block[4 * t + 1],
                block[4 * t + 2],
                block[4 * t + 3],
            ]);
        }
        for t in 16..64 {
            let s0 = w[t - 15].rotate_right(7) ^ w[t - 15].rotate_right(18) ^ (w[t - 15] >> 3);
            let s1 = w[t - 2].rotate_right(17) ^ w[t - 2].rotate_right(19) ^ (w[t - 2] >> 10);
            w[t] = w[t - 16]
                .wrapping_add(s0)
                .wrapping_add(w[t - 7])
                .wrapping_add(s1);
        }
        let [mut a, mut b, mut c, mut d, mut e, mut f, mut g, mut hh] = h;
        for t in 0..64 {
            let s1 = e.rotate_right(6) ^ e.rotate_right(11) ^ e.rotate_right(25);
            let ch = (e & f) ^ (!e & g);
            let t1 = hh
                .wrapping_add(s1)
                .wrapping_add(ch)
                .wrapping_add(K[t])
                .wrapping_add(w[t]);
            let s0 = a.rotate_right(2) ^ a.rotate_right(13) ^ a.rotate_right(22);
            let maj = (a & b) ^ (a & c) ^ (b & c);
            let t2 = s0.wrapping_add(maj);
            hh = g;
            g = f;
            f = e;
            e = d.wrapping_add(t1);
            d = c;
            c = b;
            b = a;
            a = t1.wrapping_add(t2);
        }
        h[0] = h[0].wrapping_add(a);
        h[1] = h[1].wrapping_add(b);
        h[2] = h[2].wrapping_add(c);
        h[3] = h[3].wrapping_add(d);
        h[4] = h[4].wrapping_add(e);
        h[5] = h[5].wrapping_add(f);
        h[6] = h[6].wrapping_add(g);
        h[7] = h[7].wrapping_add(hh);
    }

    let mut out = String::with_capacity(64);
    for word in h {
        for byte in word.to_be_bytes() {
            out.push(char::from_digit((byte >> 4) as u32, 16).unwrap());
            out.push(char::from_digit((byte & 0xf) as u32, 16).unwrap());
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fips_vectors() {
        assert_eq!(
            digest_hex(b""),
            "e3b0c44298fc1c149afbf4c8996fb92427ae41e4649b934ca495991b7852b855"
        );
        assert_eq!(
            digest_hex(b"abc"),
            "ba7816bf8f01cfea414140de5dae2223b00361a396177a9cb410ff61f20015ad"
        );
        assert_eq!(
            digest_hex(b"abcdbcdecdefdefgefghfghighijhijkijkljklmklmnlmnomnopnopq"),
            "248d6a61d20638b8e5c026930c3e6039a33ce45964ff2167f6ecedd419db06c1"
        );
        // One million 'a's — exercises many blocks and the length padding.
        let million = vec![b'a'; 1_000_000];
        assert_eq!(
            digest_hex(&million),
            "cdc76e5c9914fb9281a1c7e284d73e67f1809a48a497200e046d39ccc7112cd0"
        );
    }

    #[test]
    fn boundary_lengths_pad_correctly() {
        // 55/56/63/64 bytes straddle the one-vs-two-block padding split.
        for len in [55usize, 56, 63, 64, 119, 120] {
            let data = vec![0x61u8; len];
            let hex = digest_hex(&data);
            assert_eq!(hex.len(), 64, "len {len}");
            // Spot-check against a second computation (determinism).
            assert_eq!(hex, digest_hex(&data), "len {len}");
        }
        assert_eq!(
            digest_hex(&vec![0x61u8; 55]),
            "9f4390f8d30c2dd92ec9f095b65e2b9ae9b0a925a5258e241c9f1e910f734318"
        );
    }
}
