//! Serving-time estimator (paper §4.2, Eq. 1–4).
//!
//! Static-batching serving time decomposes as
//!
//!   T_serve(N, L_i, L_o) = T_prefill(N, L_i) + T_decode(N, L_i, L_o)   (1)
//!   T_decode(N, L_i, L_o) = Σ_{l=1}^{L_o} τ_decode(L_i + l, N)          (2)
//!
//! with both phases fitted as bilinear functions:
//!
//!   T_prefill(N, L_i) = p1·N·L_i + p2·N + p3·L_i + p4                   (3)
//!   τ_decode(l, N)    = d1·N·l  + d2·N + d3·l  + d4                     (4)
//!
//! Because Eq. (4) is linear in `l`, the sum in Eq. (2) has a closed form
//! (arithmetic series), so estimating a batch is O(1) — that matters
//! because the DP batcher (Alg. 1) calls `serve()` O(n²) times per
//! schedule tick.

/// Anything that can estimate T_serve(N, L_i, S). The DP batcher and the
/// offloaders are generic over this: the DES path uses the two-surface
/// `ServingTimeEstimator` (Eq. 1–4); the real-engine path uses a single
/// whole-slice surface fitted at fixed S (per-phase timings are not
/// separable once the slice is one fused AOT program).
pub trait ServeEstimate {
    fn serve_est(&self, n: u32, l_i: u32, s: u32) -> f64;

    /// Fast path for the DP batcher's inner loop: if
    /// `serve_est(n, l_i, s) = a·n + b` exactly for every `n ≥ 1`, return
    /// `Some((a, b))`. Both fitted estimators are bilinear, so at fixed
    /// (L_i, S) the surface is affine in N — unless a negative fitted
    /// coefficient would activate the `max(0, ·)` clamp, in which case the
    /// implementation must return `None` and callers fall back to
    /// `serve_est`. Default: `None`.
    fn serve_affine(&self, _l_i: u32, _s: u32) -> Option<(f64, f64)> {
        None
    }

    /// Bulk kernel for the DP batcher's window scans: fill `out[k]` with
    /// `serve_est(ns.start + k, l_i, s)` for every offset `k` covered by
    /// `ns` (`out.len()` must equal `ns.len()`).
    ///
    /// Implementations MUST be bit-identical to the scalar `serve_est`
    /// loop — the planner's differential contracts
    /// (`props_dp_differential`, the corrected suite) read candidates out
    /// of bulk-filled buffers and compare them against per-candidate
    /// reference calls. The default is exactly that scalar loop; the
    /// concrete estimators override it with chunked, autovectorization-
    /// friendly loops that evaluate the identical per-lane expression.
    fn serve_est_many(&self, ns: std::ops::Range<u32>, l_i: u32, s: u32, out: &mut [f64]) {
        debug_assert_eq!(out.len(), ns.len());
        for (o, n) in out.iter_mut().zip(ns) {
            *o = self.serve_est(n, l_i, s);
        }
    }

    /// Certified rounding slack for the corrected planner's skip
    /// certificates. When `serve_affine(l_i, s) == Some((a, b))`, return a
    /// finite σ ≥ 0 such that for all `1 ≤ n ≤ n' ≤ n_max`
    ///
    ///   `serve_est(n', l_i, s) ≥ fl(a·n + b) + (n' − n)·a − σ`
    ///
    /// where `fl(·)` is any f64 round-to-nearest evaluation order — σ must
    /// absorb the accumulated rounding of *both* `serve_est`'s own
    /// evaluation and the affine expression (including the error of the
    /// stored `a`, `b` against the exact real surface, amplified by
    /// `n_max`). The corrected DP planner uses this to lower-bound
    /// unevaluated candidates; too small a σ breaks its bit-exactness
    /// contract, too large merely prunes less. The default
    /// `f64::INFINITY` means "no certificate": the planner then evaluates
    /// every candidate (always sound). Meaningless when `serve_affine`
    /// returns `None`.
    fn serve_affine_slack(&self, _l_i: u32, _s: u32, _n_max: u32) -> f64 {
        f64::INFINITY
    }
}

/// Lane width of the chunked bulk kernels: wide enough for the
/// autovectorizer to pack 2–4 f64 vectors per chunk, small enough that the
/// remainder loop stays cheap. (std-only — no `std::simd`; the per-lane
/// expression is written exactly like the scalar path so the results are
/// bit-identical whether or not the compiler vectorizes.)
const LANES: usize = 8;

/// `(a, b)` of an affine-in-N latency `max(0, a·n + b)`, or `None` when the
/// clamp could fire for some `n ≥ 1` (i.e. unless `a ≥ 0` and `a + b ≥ 0`).
fn affine_unclamped(a: f64, b: f64) -> Option<(f64, f64)> {
    if a >= 0.0 && a + b >= 0.0 {
        Some((a, b))
    } else {
        None
    }
}

/// One bilinear latency surface: `c1·N·x + c2·N + c3·x + c4` (seconds).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinearLatency {
    pub c1: f64,
    pub c2: f64,
    pub c3: f64,
    pub c4: f64,
}

impl LinearLatency {
    #[inline]
    pub fn eval(&self, n: f64, x: f64) -> f64 {
        self.c1 * n * x + self.c2 * n + self.c3 * x + self.c4
    }

    pub fn as_vec(&self) -> [f64; 4] {
        [self.c1, self.c2, self.c3, self.c4]
    }

    pub fn from_slice(v: &[f64]) -> LinearLatency {
        LinearLatency {
            c1: v[0],
            c2: v[1],
            c3: v[2],
            c4: v[3],
        }
    }
}

/// The estimator: Eq. (3) for prefill and Eq. (4) for per-iteration decode.
#[derive(Debug, Clone, Copy)]
pub struct ServingTimeEstimator {
    pub prefill: LinearLatency,
    pub decode: LinearLatency,
}

impl ServingTimeEstimator {
    /// T_prefill(N, L_i) — Eq. (3).
    #[inline]
    pub fn prefill(&self, n: u32, l_i: u32) -> f64 {
        self.prefill.eval(n as f64, l_i as f64).max(0.0)
    }

    /// τ_decode(l, N) — Eq. (4); `l` is the cached length at this iteration.
    #[inline]
    pub fn decode_iter(&self, l: u32, n: u32) -> f64 {
        self.decode.eval(n as f64, l as f64).max(0.0)
    }

    /// T_decode(N, L_i, L_o) — Eq. (2), closed form.
    ///
    /// Σ_{l=L_i+1}^{L_i+L_o} (d1·N·l + d2·N + d3·l + d4)
    ///   = (d1·N + d3)·Σl + (d2·N + d4)·L_o
    /// with Σl = L_o·(2·L_i + L_o + 1)/2.
    #[inline]
    pub fn decode(&self, n: u32, l_i: u32, l_o: u32) -> f64 {
        if l_o == 0 {
            return 0.0;
        }
        let (nf, li, lo) = (n as f64, l_i as f64, l_o as f64);
        let sum_l = lo * (2.0 * li + lo + 1.0) / 2.0;
        let d = &self.decode;
        ((d.c1 * nf + d.c3) * sum_l + (d.c2 * nf + d.c4) * lo).max(0.0)
    }

    /// T_serve(N, L_i, L_o) — Eq. (1). Under SCLS, L_o is the slice length S.
    #[inline]
    pub fn serve(&self, n: u32, l_i: u32, l_o: u32) -> f64 {
        self.prefill(n, l_i) + self.decode(n, l_i, l_o)
    }
}

impl ServeEstimate for ServingTimeEstimator {
    #[inline]
    fn serve_est(&self, n: u32, l_i: u32, s: u32) -> f64 {
        self.serve(n, l_i, s)
    }

    #[inline]
    fn serve_affine(&self, l_i: u32, s: u32) -> Option<(f64, f64)> {
        let li = l_i as f64;
        // Prefill (Eq. 3): (p1·L + p2)·N + (p3·L + p4).
        let p = affine_unclamped(
            self.prefill.c1 * li + self.prefill.c2,
            self.prefill.c3 * li + self.prefill.c4,
        )?;
        // Decode (Eq. 2 closed form): (d1·Σl + d2·S)·N + (d3·Σl + d4·S).
        let lo = s as f64;
        let sum_l = lo * (2.0 * li + lo + 1.0) / 2.0;
        let d = affine_unclamped(
            self.decode.c1 * sum_l + self.decode.c2 * lo,
            self.decode.c3 * sum_l + self.decode.c4 * lo,
        )?;
        Some((p.0 + d.0, p.1 + d.1))
    }

    fn serve_est_many(&self, ns: std::ops::Range<u32>, l_i: u32, s: u32, out: &mut [f64]) {
        debug_assert_eq!(out.len(), ns.len());
        if s == 0 {
            // `decode` early-returns 0.0 at L_o == 0; the fused closed form
            // below differs in signed-zero handling, so keep the scalar
            // path for bit-identity.
            for (o, n) in out.iter_mut().zip(ns) {
                *o = self.serve_est(n, l_i, s);
            }
            return;
        }
        let li = l_i as f64;
        let lo = s as f64;
        let sum_l = lo * (2.0 * li + lo + 1.0) / 2.0;
        let p = self.prefill;
        let d = self.decode;
        // Per-lane expression identical (ops and order) to
        // `prefill(n, l_i) + decode(n, l_i, s)`, so results are bit-equal
        // to the scalar loop with or without vectorization.
        let lane = move |nf: f64| -> f64 {
            let pre = (p.c1 * nf * li + p.c2 * nf + p.c3 * li + p.c4).max(0.0);
            let dec = ((d.c1 * nf + d.c3) * sum_l + (d.c2 * nf + d.c4) * lo).max(0.0);
            pre + dec
        };
        let n0 = ns.start;
        let mut base = 0usize;
        let mut chunks = out.chunks_exact_mut(LANES);
        for chunk in &mut chunks {
            let nb = n0 + base as u32;
            for (k, o) in chunk.iter_mut().enumerate() {
                *o = lane((nb + k as u32) as f64);
            }
            base += LANES;
        }
        let nb = n0 + base as u32;
        for (k, o) in chunks.into_remainder().iter_mut().enumerate() {
            *o = lane((nb + k as u32) as f64);
        }
    }

    fn serve_affine_slack(&self, l_i: u32, s: u32, n_max: u32) -> f64 {
        // Forward-error budget for the certificate inequality (see the
        // trait doc): `serve_est` accumulates ~12 roundings and the affine
        // expression plus the stored (a, b)'s own construction ~10 more,
        // each bounded by ε times the sum of absolute term magnitudes at
        // n = n_max (the magnitude sum is computed from the raw
        // coefficients, NOT from |a|/|b| — negative fitted coefficients can
        // cancel inside a and b, hiding the intermediate magnitudes that
        // actually round). 64ε leaves ~3x headroom over that worst case.
        let li = l_i as f64;
        let lo = s as f64;
        let nf = n_max as f64;
        let sum_l = (lo * (2.0 * li + lo + 1.0) / 2.0).abs();
        let p = &self.prefill;
        let d = &self.decode;
        let mag = p.c1.abs() * nf * li
            + p.c2.abs() * nf
            + p.c3.abs() * li
            + p.c4.abs()
            + d.c1.abs() * nf * sum_l
            + d.c2.abs() * nf * lo
            + d.c3.abs() * sum_l
            + d.c4.abs() * lo;
        mag * (f64::EPSILON * 64.0)
    }
}

/// A single whole-slice bilinear surface T_slice(N, L_i) fitted at fixed S
/// (the real-engine estimator; S baked in at fit time).
#[derive(Debug, Clone, Copy)]
pub struct SliceTimeEstimator {
    pub surface: LinearLatency,
}

impl ServeEstimate for SliceTimeEstimator {
    #[inline]
    fn serve_est(&self, n: u32, l_i: u32, _s: u32) -> f64 {
        self.surface.eval(n as f64, l_i as f64).max(0.0)
    }

    #[inline]
    fn serve_affine(&self, l_i: u32, _s: u32) -> Option<(f64, f64)> {
        let li = l_i as f64;
        affine_unclamped(
            self.surface.c1 * li + self.surface.c2,
            self.surface.c3 * li + self.surface.c4,
        )
    }

    fn serve_est_many(&self, ns: std::ops::Range<u32>, l_i: u32, _s: u32, out: &mut [f64]) {
        debug_assert_eq!(out.len(), ns.len());
        let li = l_i as f64;
        let c = self.surface;
        // Identical expression to `serve_est` per lane (bit-equal results).
        let lane =
            move |nf: f64| -> f64 { (c.c1 * nf * li + c.c2 * nf + c.c3 * li + c.c4).max(0.0) };
        let n0 = ns.start;
        let mut base = 0usize;
        let mut chunks = out.chunks_exact_mut(LANES);
        for chunk in &mut chunks {
            let nb = n0 + base as u32;
            for (k, o) in chunk.iter_mut().enumerate() {
                *o = lane((nb + k as u32) as f64);
            }
            base += LANES;
        }
        let nb = n0 + base as u32;
        for (k, o) in chunks.into_remainder().iter_mut().enumerate() {
            *o = lane((nb + k as u32) as f64);
        }
    }

    fn serve_affine_slack(&self, l_i: u32, _s: u32, n_max: u32) -> f64 {
        // Same budget argument as `ServingTimeEstimator::serve_affine_slack`
        // over the single whole-slice surface (fewer roundings, same 64ε
        // headroom; magnitudes from raw coefficients to survive
        // cancellation in a/b).
        let li = l_i as f64;
        let nf = n_max as f64;
        let c = &self.surface;
        let mag = c.c1.abs() * nf * li + c.c2.abs() * nf + c.c3.abs() * li + c.c4.abs();
        mag * (f64::EPSILON * 64.0)
    }
}

/// Fitted KV-transfer cost model: the wall-clock stall a migrated request
/// pays before it is servable on its new worker, as an affine function of
/// the resident KV tokens being shipped (`base_s + per_token_s * tokens`).
///
/// The affine shape mirrors the `ServeEstimate` family: a fixed per-transfer
/// setup term (connection + metadata) plus a bandwidth-limited linear term.
/// `from_bandwidth` builds the common case from a tokens-per-second link
/// rate with a small fixed setup cost.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TransferCost {
    /// Fixed per-migration setup time in seconds.
    pub base_s: f64,
    /// Seconds per resident KV token shipped.
    pub per_token_s: f64,
}

impl TransferCost {
    /// Default per-transfer setup cost (seconds) used by `from_bandwidth`.
    pub const DEFAULT_BASE_S: f64 = 0.01;

    /// Build a cost model from a link bandwidth in KV tokens per second.
    ///
    /// Panics if `tokens_per_s` is not finite and positive (the CLI layer
    /// rejects such values with a friendly error before reaching here).
    pub fn from_bandwidth(tokens_per_s: f64) -> Self {
        assert!(
            tokens_per_s.is_finite() && tokens_per_s > 0.0,
            "KV-transfer bandwidth must be finite and positive"
        );
        TransferCost {
            base_s: Self::DEFAULT_BASE_S,
            per_token_s: 1.0 / tokens_per_s,
        }
    }

    /// Stall time in seconds for shipping `tokens` resident KV tokens.
    #[inline]
    pub fn stall(&self, tokens: u64) -> f64 {
        self.base_s + self.per_token_s * tokens as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn est() -> ServingTimeEstimator {
        ServingTimeEstimator {
            prefill: LinearLatency {
                c1: 1e-4,
                c2: 1e-3,
                c3: 1e-4,
                c4: 1e-2,
            },
            decode: LinearLatency {
                c1: 5e-7,
                c2: 7e-4,
                c3: 2.5e-6,
                c4: 2e-2,
            },
        }
    }

    #[test]
    fn prefill_matches_formula() {
        let e = est();
        let t = e.prefill(8, 1024);
        let expect = 1e-4 * 8.0 * 1024.0 + 1e-3 * 8.0 + 1e-4 * 1024.0 + 1e-2;
        assert!((t - expect).abs() < 1e-12);
    }

    #[test]
    fn decode_closed_form_equals_loop() {
        let e = est();
        for &(n, li, lo) in &[(1u32, 10u32, 5u32), (8, 1024, 128), (12, 300, 1), (4, 0, 64)] {
            let closed = e.decode(n, li, lo);
            let mut acc = 0.0;
            for l in (li + 1)..=(li + lo) {
                acc += e.decode_iter(l, n);
            }
            assert!(
                (closed - acc).abs() < 1e-9 * acc.max(1.0),
                "n={n} li={li} lo={lo}: {closed} vs {acc}"
            );
        }
    }

    #[test]
    fn zero_iterations_costs_nothing() {
        assert_eq!(est().decode(8, 100, 0), 0.0);
    }

    #[test]
    fn serve_is_sum() {
        let e = est();
        let t = e.serve(4, 256, 128);
        assert!((t - (e.prefill(4, 256) + e.decode(4, 256, 128))).abs() < 1e-12);
    }

    #[test]
    fn monotone_in_batch_size_and_lengths() {
        let e = est();
        assert!(e.serve(8, 256, 128) > e.serve(4, 256, 128));
        assert!(e.serve(8, 512, 128) > e.serve(8, 256, 128));
        assert!(e.serve(8, 256, 256) > e.serve(8, 256, 128));
    }

    #[test]
    fn bulk_kernel_is_bit_identical_to_scalar_loop() {
        // Every remainder width 0..LANES plus multi-chunk lengths, both
        // surfaces, including a clamp-activating negative fit.
        let two_surface = est();
        let clampy = ServingTimeEstimator {
            prefill: LinearLatency {
                c1: 1e-4,
                c2: -2e-3,
                c3: 1e-4,
                c4: -0.5,
            },
            decode: LinearLatency {
                c1: 5e-7,
                c2: 7e-4,
                c3: -2.5e-6,
                c4: -2e-2,
            },
        };
        let slice = SliceTimeEstimator {
            surface: LinearLatency {
                c1: 2e-5,
                c2: 3e-4,
                c3: -1e-5,
                c4: 0.01,
            },
        };
        let ests: [&dyn ServeEstimate; 3] = [&two_surface, &clampy, &slice];
        for est in ests {
            for &(l_i, s) in &[(1u32, 16u32), (512, 128), (1024, 0), (7, 1)] {
                for n0 in [1u32, 2, 5] {
                    for len in 0..=(3 * super::LANES + 1) {
                        let mut out = vec![f64::NAN; len];
                        est.serve_est_many(n0..n0 + len as u32, l_i, s, &mut out);
                        for (k, &got) in out.iter().enumerate() {
                            let want = est.serve_est(n0 + k as u32, l_i, s);
                            assert_eq!(
                                got.to_bits(),
                                want.to_bits(),
                                "n={} l_i={l_i} s={s}: {got} vs {want}",
                                n0 + k as u32
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn affine_slack_certifies_the_surface() {
        // Wherever serve_affine applies, every float serve_est value must
        // sit within the certified slack of the affine anchor — the
        // inequality the corrected DP's skip certificates rely on.
        let e = est();
        for &(l_i, s) in &[(1u32, 16u32), (64, 128), (1024, 512)] {
            let n_max = 2048u32;
            let (a, b) = e.serve_affine(l_i, s).expect("non-negative fit is affine");
            let slack = e.serve_affine_slack(l_i, s, n_max);
            assert!(slack.is_finite() && slack >= 0.0);
            for n in [1u32, 2, 7, 100, 1000, 2048] {
                let v = e.serve_est(n, l_i, s);
                for anchor in [1u32, n / 2, n] {
                    let anchor = anchor.max(1);
                    let lo = (a * anchor as f64 + b) + (n - anchor) as f64 * a - slack;
                    assert!(
                        v >= lo,
                        "serve_est({n},{l_i},{s})={v} below certified bound {lo}"
                    );
                }
            }
        }
    }

    #[test]
    fn default_trait_hooks_are_safe() {
        // A minimal estimator: the default bulk kernel is the scalar loop
        // and the default slack disables certificates.
        struct Flat;
        impl ServeEstimate for Flat {
            fn serve_est(&self, n: u32, _l: u32, _s: u32) -> f64 {
                n as f64
            }
        }
        let mut out = [0.0f64; 5];
        Flat.serve_est_many(3..8, 10, 10, &mut out);
        assert_eq!(out, [3.0, 4.0, 5.0, 6.0, 7.0]);
        assert_eq!(Flat.serve_affine_slack(10, 10, 100), f64::INFINITY);
    }

    #[test]
    fn negative_fits_clamped() {
        let e = ServingTimeEstimator {
            prefill: LinearLatency {
                c1: 0.0,
                c2: 0.0,
                c3: 0.0,
                c4: -5.0,
            },
            decode: LinearLatency {
                c1: 0.0,
                c2: 0.0,
                c3: 0.0,
                c4: -5.0,
            },
        };
        assert_eq!(e.serve(1, 1, 1), 0.0);
    }

    #[test]
    fn transfer_cost_is_affine_in_tokens() {
        let c = TransferCost {
            base_s: 0.5,
            per_token_s: 0.001,
        };
        assert_eq!(c.stall(0), 0.5);
        assert!((c.stall(1000) - 1.5).abs() < 1e-12);
        // Monotone in token count.
        assert!(c.stall(2000) > c.stall(1000));
    }

    #[test]
    fn transfer_cost_from_bandwidth() {
        let c = TransferCost::from_bandwidth(10_000.0);
        assert_eq!(c.base_s, TransferCost::DEFAULT_BASE_S);
        assert!((c.stall(10_000) - (TransferCost::DEFAULT_BASE_S + 1.0)).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "finite and positive")]
    fn transfer_cost_rejects_zero_bandwidth() {
        let _ = TransferCost::from_bandwidth(0.0);
    }
}
