//! Adaptive schedule-interval update (paper §4.6, Eq. 12):
//!
//!   T ← max( λ · min_w T_load(w), Γ )
//!
//! Light cluster load ⇒ short interval (requests don't linger in the
//! pool); deep worker queues ⇒ long interval (more requests accumulate per
//! tick, bigger batches). λ < 1 hedges against over-estimated load; Γ
//! prevents starving the batcher when load is under-estimated.

use crate::offloader::LoadLedger;

#[derive(Debug, Clone)]
pub enum IntervalController {
    /// Fixed interval (the PM/AB/LB ablations use Γ).
    Fixed(f64),
    /// Eq. (12) (full SCLS).
    Adaptive { lambda: f64, gamma: f64 },
}

impl IntervalController {
    /// Next schedule interval given the current worker-load ledger.
    pub fn next_interval(&self, ledger: &LoadLedger) -> f64 {
        match self {
            IntervalController::Fixed(t) => *t,
            IntervalController::Adaptive { lambda, gamma } => {
                (lambda * ledger.min()).max(*gamma)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fixed_is_constant() {
        let c = IntervalController::Fixed(3.0);
        let mut l = LoadLedger::new(2);
        assert_eq!(c.next_interval(&l), 3.0);
        l.add(0, 100.0);
        assert_eq!(c.next_interval(&l), 3.0);
    }

    #[test]
    fn adaptive_floors_at_gamma() {
        let c = IntervalController::Adaptive {
            lambda: 0.5,
            gamma: 6.0,
        };
        let l = LoadLedger::new(2); // all idle -> min load 0
        assert_eq!(c.next_interval(&l), 6.0);
    }

    #[test]
    fn adaptive_grows_with_min_load() {
        let c = IntervalController::Adaptive {
            lambda: 0.5,
            gamma: 6.0,
        };
        let mut l = LoadLedger::new(2);
        l.add(0, 40.0);
        l.add(1, 20.0); // min = 20 -> T = 10
        assert_eq!(c.next_interval(&l), 10.0);
    }

    #[test]
    fn adaptive_tracks_min_not_max() {
        let c = IntervalController::Adaptive {
            lambda: 0.5,
            gamma: 1.0,
        };
        let mut l = LoadLedger::new(3);
        l.add(0, 100.0);
        l.add(1, 100.0);
        // worker 2 idle -> interval = gamma, keeping the idle worker fed
        assert_eq!(c.next_interval(&l), 1.0);
    }
}
