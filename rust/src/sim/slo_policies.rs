//! SLO-aware scheduling policies on the slice ladder.
//!
//! Three policies that spend slicing's predictable per-batch serving time
//! on deadlines instead of raw throughput:
//!
//! * [`DeadlineSclsPolicy`] (**D-SCLS**) — SCLS whose ladder entry rung is
//!   seeded from *deadline slack* instead of a length prediction: a
//!   request that can only afford k more passes before its deadline enters
//!   at the rung whose budget covers the remaining ladder in k passes
//!   (tight slack ⇒ one big pass, no re-prefill churn). Requests that are
//!   deadline-infeasible at admission — or whose deadline expires while
//!   re-queued — are *shed* and counted ([`SimCtx::record_shed`]) rather
//!   than served into a guaranteed miss.
//! * [`RankedSlicePolicy`] with [`RankKey::PredictedRemaining`]
//!   (**P-SRPT**) — preemptive shortest-remaining-predicted-time: each
//!   tick the pool is ordered by predicted remaining generation (from the
//!   [`LengthPredictor`]) and the shortest work is batched and placed
//!   first, which minimizes mean sojourn and drags TTFT/deadline tails
//!   down under overload. Slice boundaries are the preemption points.
//! * [`RankedSlicePolicy`] with [`RankKey::DeadlineSlack`] (**SW-SLO**) —
//!   sliding-window SLO-aware batching: per tick only the `window` most
//!   deadline-critical pooled requests are admitted to the DP batcher
//!   (earliest-slack-first), so under overload the batcher composes
//!   batches from requests that can still make their deadlines instead of
//!   the whole FCFS backlog.
//!
//! All three interpret the SCLS spec axes (uncapped DP batching, max-min
//! offload, Eq. (12) adaptive interval) and reuse the static-batching
//! serving helpers from [`crate::sim::policies`]. Like SCLS-CB / P-CB they
//! keep the default no-op elastic-fleet hooks: on fault-free traces they
//! are deterministic, and `FaultPlan::none()` runs are byte-identical to
//! plain [`crate::sim::driver::run_policy`].

use std::collections::VecDeque;

use crate::batcher::{dp_batch_sorted_into, DpBatcherConfig, DpScratch};
use crate::core::{Batch, Request};
use crate::engine::presets::EnginePreset;
use crate::engine::sim::SimEngine;
use crate::estimator::{MemoryEstimator, ServingTimeEstimator};
use crate::metrics::RunMetrics;
use crate::offloader::{LoadLedger, RoundRobin};
use crate::predictor::LengthPredictor;
use crate::scheduler::policy::{SchedulingPolicy, SimCtx};
use crate::scheduler::spec::{BatchingSpec, IntervalSpec, OffloadSpec, SchedulerSpec};
use crate::scheduler::{IntervalController, RequestPool};
use crate::sim::driver::{fitted_estimator, SimConfig};
use crate::sim::policies::{settle_batch, start_static_batch, ServingSlot};

/// Per-worker state shared by the SLO-aware static-batching policies:
/// queued `(iteration budget, batch)` pairs plus the in-flight slot.
struct SloWorkerState {
    batch_queue: VecDeque<(u32, Batch)>,
    serving: Option<ServingSlot>,
    engine: SimEngine,
    last_done: f64,
}

impl SloWorkerState {
    /// A cold worker under index `w` on a `salt`-decorrelated seed stream
    /// (each policy family uses its own salt, like the built-ins).
    fn cold(preset: &EnginePreset, seed: u64, max_gen_len: u32, w: usize, salt: u64) -> Self {
        SloWorkerState {
            batch_queue: VecDeque::new(),
            serving: None,
            engine: SimEngine::new(
                preset.latency(seed ^ (w as u64).wrapping_mul(salt)),
                max_gen_len,
            ),
            last_done: 0.0,
        }
    }
}

// ---------------------------------------------------------------------------
// D-SCLS: deadline-seeded slice ladder with infeasibility shedding
// ---------------------------------------------------------------------------

/// **D-SCLS** — deadline-aware SCLS (see the module docs).
///
/// Admission: a request with a deadline is shed immediately if even one
/// single-request pass cannot finish before it; otherwise its entry rung
/// is `⌈max_rung / passes_affordable⌉` where `passes_affordable` is how
/// many single-pass estimates fit in the remaining slack. Deadline-free
/// requests enter at rung 1 and behave exactly like vanilla SCLS traffic.
/// Unfinished requests re-queue to rung 1 (one more pass of S from there
/// on) unless their deadline has already expired, in which case they are
/// shed at the boundary instead of burning further cluster time.
pub struct DeadlineSclsPolicy {
    spec: SchedulerSpec,
    est: ServingTimeEstimator,
    mem: MemoryEstimator,
    ledger: LoadLedger,
    rr: RoundRobin,
    interval: IntervalController,
    /// One pool per rung: `pools[b-1]` gets an iteration budget of `b·S`.
    pools: Vec<RequestPool>,
    workers: Vec<SloWorkerState>,
    max_gen_len: u32,
    max_rung: u32,
    tick_armed: bool,
    // Reused per-tick buffers (allocation-lean discipline from PR 1).
    tick_reqs: Vec<Request>,
    batch_buf: Vec<Batch>,
    staged: Vec<(u32, Batch)>,
    dp_scratch: DpScratch,
}

impl DeadlineSclsPolicy {
    pub fn new(spec: &SchedulerSpec, cfg: &SimConfig) -> DeadlineSclsPolicy {
        assert!(cfg.workers > 0);
        let s = spec.slice_len.max(1);
        let max_rung = ((cfg.max_gen_len + s - 1) / s).max(1);
        let workers: Vec<SloWorkerState> = (0..cfg.workers)
            .map(|w| SloWorkerState::cold(&cfg.engine, cfg.seed, cfg.max_gen_len, w, 0xD51C))
            .collect();
        let interval = match spec.interval {
            IntervalSpec::Fixed(t) => IntervalController::Fixed(t),
            IntervalSpec::Adaptive { lambda, gamma } => {
                IntervalController::Adaptive { lambda, gamma }
            }
            // Deadline seeding pools per rung, so the policy is inherently
            // ticked even under an immediate-interval spec.
            IntervalSpec::Immediate => IntervalController::Fixed(cfg.engine.gamma),
        };
        DeadlineSclsPolicy {
            spec: spec.clone(),
            est: fitted_estimator(&cfg.engine, cfg.seed),
            mem: cfg.engine.memory_estimator(),
            ledger: LoadLedger::new(cfg.workers),
            rr: RoundRobin::new(cfg.workers),
            interval,
            pools: (0..max_rung).map(|_| RequestPool::new()).collect(),
            workers,
            max_gen_len: cfg.max_gen_len,
            max_rung,
            tick_armed: false,
            tick_reqs: Vec::new(),
            batch_buf: Vec::new(),
            staged: Vec::new(),
            dp_scratch: DpScratch::new(),
        }
    }

    /// Iteration budget of rung `b` (the whole ladder up to the rung).
    fn rung_budget(&self, b: u32) -> u32 {
        (b * self.spec.slice_len).min(self.max_gen_len).max(1)
    }

    fn pooled(&self) -> usize {
        self.pools.iter().map(|p| p.len()).sum()
    }

    /// Start serving on worker `w` if idle and work is queued.
    fn try_start(&mut self, w: usize, ctx: &mut SimCtx) {
        let ws = &mut self.workers[w];
        if ws.serving.is_some() {
            return;
        }
        let Some((budget, batch)) = ws.batch_queue.pop_front() else {
            return;
        };
        start_static_batch(&mut ws.engine, &mut ws.serving, w, batch, budget, 0.0, ctx);
    }
}

impl SchedulingPolicy for DeadlineSclsPolicy {
    fn init(&mut self, ctx: &mut SimCtx) {
        self.pools[0].reserve(ctx.arrivals_left().min(1 << 16));
        ctx.tick_at(0.0);
        self.tick_armed = true;
    }

    fn on_arrival(&mut self, req: Request, ctx: &mut SimCtx) {
        let s = self.spec.slice_len.max(1);
        let Some(d) = req.slo.deadline else {
            // No deadline: vanilla SCLS bottom-of-ladder entry.
            self.pools[0].push(req);
            return;
        };
        let due = req.arrival + d;
        let est_pass = self.est.serve_est(1, req.input_len, s);
        if ctx.now + est_pass > due {
            // Even an immediate dedicated pass misses: shed at admission.
            ctx.record_shed(&req);
            return;
        }
        let slack = due - ctx.now;
        // How many single-pass estimates still fit before the deadline
        // (the f64→u32 cast saturates on huge slacks).
        let affordable = ((slack / est_pass).floor() as u32).max(1);
        let rung = ((self.max_rung + affordable - 1) / affordable).clamp(1, self.max_rung);
        self.pools[rung as usize - 1].push(req);
    }

    fn on_tick(&mut self, ctx: &mut SimCtx) {
        self.tick_armed = false;
        let drained = self.pooled();
        if drained > 0 {
            ctx.observe_pool(drained);
            // DP-batch each rung with the rung's iteration budget, then
            // offload everything together (urgent rungs batch like any
            // other — urgency was spent deciding the budget).
            for b in 1..=self.max_rung {
                if self.pools[b as usize - 1].is_empty() {
                    continue;
                }
                let budget = self.rung_budget(b);
                self.pools[b as usize - 1].drain_sorted_into(&mut self.tick_reqs);
                let dp_cfg = DpBatcherConfig {
                    slice_len: budget,
                    max_batch_size: match self.spec.batching {
                        BatchingSpec::Dp { max_batch_size } => max_batch_size,
                        BatchingSpec::WorkerFcfs { batch_size } => Some(batch_size),
                    },
                    // D-SCLS stamps no predictions, so the corrected DP
                    // would change nothing — keep the optimized planner.
                    pred_corrected: false,
                };
                dp_batch_sorted_into(
                    &mut self.tick_reqs,
                    &self.est,
                    &self.mem,
                    &dp_cfg,
                    &mut self.dp_scratch,
                    &mut self.batch_buf,
                );
                self.staged
                    .extend(self.batch_buf.drain(..).map(|batch| (budget, batch)));
            }
            match self.spec.offload {
                OffloadSpec::MaxMin => {
                    // LPT over all rung batches (paper §4.5).
                    self.staged
                        .sort_by(|a, b| b.1.est_serve_time.total_cmp(&a.1.est_serve_time));
                    let mut staged = std::mem::take(&mut self.staged);
                    for (budget, batch) in staged.drain(..) {
                        let w = self.ledger.try_argmin().expect("fixed fleet never drains");
                        self.ledger.add(w, batch.est_serve_time);
                        self.workers[w].batch_queue.push_back((budget, batch));
                        self.try_start(w, ctx);
                    }
                    self.staged = staged;
                }
                OffloadSpec::RoundRobin => {
                    let mut staged = std::mem::take(&mut self.staged);
                    for (budget, batch) in staged.drain(..) {
                        let w = self.rr.next_worker();
                        self.ledger.add(w, batch.est_serve_time);
                        self.workers[w].batch_queue.push_back((budget, batch));
                        self.try_start(w, ctx);
                    }
                    self.staged = staged;
                }
            }
        }
        // Re-arm while any work can still appear.
        let work_pending = ctx.arrivals_left() > 0
            || self.pooled() > 0
            || self
                .workers
                .iter()
                .any(|w| w.serving.is_some() || !w.batch_queue.is_empty());
        if work_pending {
            let t = self.interval.next_interval(&self.ledger);
            ctx.tick_at(ctx.now + t.max(1e-3));
            self.tick_armed = true;
        }
    }

    fn on_worker_done(&mut self, w: usize, ctx: &mut SimCtx) {
        let Some(slot) = self.workers[w].serving.take() else {
            return;
        };
        let new_tokens = slot.new_tokens_total();
        let batch = settle_batch(slot, ctx.now);
        self.ledger.complete(w, batch.est_serve_time);
        self.workers[w].last_done = ctx.now;
        // Telemetry sample at the slice boundary (static batching releases
        // the batch here, so KV-in-use is 0 by construction).
        ctx.record_served(w, new_tokens, 0, self.workers[w].batch_queue.len());
        for r in batch.requests {
            if r.is_finished() {
                ctx.record_completion(&r);
            } else if r.slo.deadline.is_some_and(|d| ctx.now >= r.arrival + d) {
                // The deadline expired mid-ladder: shed instead of burning
                // more passes on a guaranteed miss.
                ctx.record_shed(&r);
            } else {
                // One more pass of S — vanilla SCLS from here on.
                self.pools[0].push(r);
            }
        }
        self.try_start(w, ctx);
    }

    fn finish(&mut self, metrics: &mut RunMetrics) {
        metrics.worker_completion = self.workers.iter().map(|w| w.last_done).collect();
    }
}

// ---------------------------------------------------------------------------
// P-SRPT / SW-SLO: rank-ordered slice scheduling
// ---------------------------------------------------------------------------

/// What [`RankedSlicePolicy`] orders the pool by each tick (ascending:
/// smaller key = more urgent = batched and placed first).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RankKey {
    /// Predicted remaining generation length (P-SRPT): shortest predicted
    /// remaining work first.
    PredictedRemaining,
    /// Seconds until the deadline (SW-SLO): earliest slack first;
    /// deadline-free requests rank last (+∞).
    DeadlineSlack,
}

/// Rank of one pooled request at virtual time `now` (free function so the
/// sort closure doesn't fight the borrow checker over `self`).
fn rank_of(key: RankKey, max_gen_len: u32, now: f64, r: &Request) -> f64 {
    match key {
        RankKey::PredictedRemaining => r
            .predicted_gen
            .unwrap_or(max_gen_len)
            .saturating_sub(r.generated)
            .max(1) as f64,
        RankKey::DeadlineSlack => match r.slo.deadline {
            Some(d) => r.arrival + d - now,
            None => f64::INFINITY,
        },
    }
}

/// Rank-ordered chunks this many requests wide are handed to the DP
/// batcher, so batches never mix very different urgencies.
const RANK_CHUNK: usize = 64;

/// Admission window per worker for the sliding-window mode
/// ([`RankKey::DeadlineSlack`]), floored at [`RANK_CHUNK`].
const WINDOW_PER_WORKER: usize = 16;

/// **P-SRPT** / **SW-SLO** — rank-ordered slice scheduling (see the
/// module docs). Each tick the pool is sorted by the [`RankKey`]
/// (ascending, ties by request id), optionally truncated to the `window`
/// most urgent requests, cut into rank-ordered [`RANK_CHUNK`]-wide chunks,
/// DP-batched within each chunk, and placed most-urgent-first so the
/// least-loaded workers serve the most critical work. Unfinished requests
/// re-enter the pool at the slice boundary and are re-ranked next tick —
/// for P-SRPT their remaining work has shrunk by a slice, which is exactly
/// the preemptive part of SRPT.
pub struct RankedSlicePolicy {
    spec: SchedulerSpec,
    key: RankKey,
    /// Ranks P-SRPT's pool; also fed completion feedback so online
    /// predictors refit. `None` for SW-SLO.
    predictor: Option<Box<dyn LengthPredictor>>,
    /// Per-tick admission cap (SW-SLO); `None` admits the whole pool.
    window: Option<usize>,
    est: ServingTimeEstimator,
    mem: MemoryEstimator,
    ledger: LoadLedger,
    rr: RoundRobin,
    interval: IntervalController,
    pool: Vec<Request>,
    workers: Vec<SloWorkerState>,
    max_gen_len: u32,
    tick_armed: bool,
    pred_corrected: bool,
    // Reused per-tick buffers.
    admit_buf: Vec<Request>,
    tick_reqs: Vec<Request>,
    batch_buf: Vec<Batch>,
    staged: Vec<Batch>,
    dp_scratch: DpScratch,
}

impl RankedSlicePolicy {
    pub fn new(
        spec: &SchedulerSpec,
        cfg: &SimConfig,
        key: RankKey,
        predictor: Option<Box<dyn LengthPredictor>>,
    ) -> RankedSlicePolicy {
        assert!(cfg.workers > 0);
        let workers: Vec<SloWorkerState> = (0..cfg.workers)
            .map(|w| SloWorkerState::cold(&cfg.engine, cfg.seed, cfg.max_gen_len, w, 0x4A7B))
            .collect();
        let interval = match spec.interval {
            IntervalSpec::Fixed(t) => IntervalController::Fixed(t),
            IntervalSpec::Adaptive { lambda, gamma } => {
                IntervalController::Adaptive { lambda, gamma }
            }
            IntervalSpec::Immediate => IntervalController::Fixed(cfg.engine.gamma),
        };
        let window = match key {
            RankKey::DeadlineSlack => Some((cfg.workers * WINDOW_PER_WORKER).max(RANK_CHUNK)),
            RankKey::PredictedRemaining => None,
        };
        // The corrected DP only helps when predictions are stamped.
        let pred_corrected = cfg.pred_corrected_dp && predictor.is_some();
        RankedSlicePolicy {
            spec: spec.clone(),
            key,
            predictor,
            window,
            est: fitted_estimator(&cfg.engine, cfg.seed),
            mem: cfg.engine.memory_estimator(),
            ledger: LoadLedger::new(cfg.workers),
            rr: RoundRobin::new(cfg.workers),
            interval,
            pool: Vec::new(),
            workers,
            max_gen_len: cfg.max_gen_len,
            tick_armed: false,
            pred_corrected,
            admit_buf: Vec::new(),
            tick_reqs: Vec::new(),
            batch_buf: Vec::new(),
            staged: Vec::new(),
            dp_scratch: DpScratch::new(),
        }
    }

    /// Start serving on worker `w` if idle and work is queued.
    fn try_start(&mut self, w: usize, ctx: &mut SimCtx) {
        let ws = &mut self.workers[w];
        if ws.serving.is_some() {
            return;
        }
        let Some((budget, batch)) = ws.batch_queue.pop_front() else {
            return;
        };
        start_static_batch(&mut ws.engine, &mut ws.serving, w, batch, budget, 0.0, ctx);
    }

    /// Place one batch per the spec's offload axis (most urgent batches
    /// are placed first, so max-min hands them the least-loaded workers).
    fn place(&mut self, batch: Batch, ctx: &mut SimCtx) {
        let w = match self.spec.offload {
            OffloadSpec::MaxMin => self.ledger.try_argmin().expect("fixed fleet never drains"),
            OffloadSpec::RoundRobin => self.rr.next_worker(),
        };
        self.ledger.add(w, batch.est_serve_time);
        self.workers[w]
            .batch_queue
            .push_back((self.spec.slice_len.max(1), batch));
        self.try_start(w, ctx);
    }
}

impl SchedulingPolicy for RankedSlicePolicy {
    fn init(&mut self, ctx: &mut SimCtx) {
        self.pool.reserve(ctx.arrivals_left().min(1 << 16));
        ctx.tick_at(0.0);
        self.tick_armed = true;
    }

    fn on_arrival(&mut self, mut req: Request, _ctx: &mut SimCtx) {
        if let Some(p) = self.predictor.as_ref() {
            req.predicted_gen = Some(p.predict(&req).max(1));
        }
        self.pool.push(req);
    }

    fn on_tick(&mut self, ctx: &mut SimCtx) {
        self.tick_armed = false;
        if !self.pool.is_empty() {
            let (key, mgl, now) = (self.key, self.max_gen_len, ctx.now);
            self.pool.sort_by(|a, b| {
                rank_of(key, mgl, now, a)
                    .total_cmp(&rank_of(key, mgl, now, b))
                    .then(a.id.cmp(&b.id))
            });
            let admit = match self.window {
                Some(w) => self.pool.len().min(w),
                None => self.pool.len(),
            };
            ctx.observe_pool(admit);
            let mut admitted = std::mem::take(&mut self.admit_buf);
            admitted.extend(self.pool.drain(..admit));
            while !admitted.is_empty() {
                let take = admitted.len().min(RANK_CHUNK);
                self.tick_reqs.extend(admitted.drain(..take));
                // The DP batcher needs input-length order within the chunk
                // (Alg. 1's contiguity argument); rank order is preserved
                // *across* chunks.
                self.tick_reqs
                    .sort_by(|a, b| a.input_len.cmp(&b.input_len).then(a.id.cmp(&b.id)));
                let dp_cfg = DpBatcherConfig {
                    slice_len: self.spec.slice_len.max(1),
                    max_batch_size: match self.spec.batching {
                        BatchingSpec::Dp { max_batch_size } => max_batch_size,
                        BatchingSpec::WorkerFcfs { batch_size } => Some(batch_size),
                    },
                    pred_corrected: self.pred_corrected,
                };
                dp_batch_sorted_into(
                    &mut self.tick_reqs,
                    &self.est,
                    &self.mem,
                    &dp_cfg,
                    &mut self.dp_scratch,
                    &mut self.batch_buf,
                );
                for _ in 0..self.dp_scratch.corrected_batches() {
                    ctx.record_corrected_batch();
                }
                self.staged.extend(self.batch_buf.drain(..));
            }
            self.admit_buf = admitted;
            let mut staged = std::mem::take(&mut self.staged);
            for batch in staged.drain(..) {
                self.place(batch, ctx);
            }
            self.staged = staged;
        }
        let work_pending = ctx.arrivals_left() > 0
            || !self.pool.is_empty()
            || self
                .workers
                .iter()
                .any(|w| w.serving.is_some() || !w.batch_queue.is_empty());
        if work_pending {
            let t = self.interval.next_interval(&self.ledger);
            ctx.tick_at(ctx.now + t.max(1e-3));
            self.tick_armed = true;
        }
    }

    fn on_worker_done(&mut self, w: usize, ctx: &mut SimCtx) {
        let Some(slot) = self.workers[w].serving.take() else {
            return;
        };
        let new_tokens = slot.new_tokens_total();
        let batch = settle_batch(slot, ctx.now);
        self.ledger.complete(w, batch.est_serve_time);
        self.workers[w].last_done = ctx.now;
        // Telemetry sample at the slice boundary (static batching releases
        // the batch here, so KV-in-use is 0 by construction).
        ctx.record_served(w, new_tokens, 0, self.workers[w].batch_queue.len());
        for r in batch.requests {
            if r.is_finished() {
                if let Some(p) = self.predictor.as_mut() {
                    if p.observe(&r, r.generated) {
                        ctx.record_refit();
                    }
                }
                ctx.record_completion(&r);
            } else {
                // Back to the pool for re-ranking: preemption at the
                // slice boundary.
                self.pool.push(r);
            }
        }
        self.try_start(w, ctx);
    }

    fn finish(&mut self, metrics: &mut RunMetrics) {
        metrics.worker_completion = self.workers.iter().map(|w| w.last_done).collect();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::presets::{EngineKind, EnginePreset};
    use crate::metrics::NullSink;
    use crate::sim::driver::run_policy;
    use crate::slo::{stamp_trace, SloSpec, TenantMix};
    use crate::workload::distributions::WorkloadKind;
    use crate::workload::{Trace, TraceConfig};

    fn small_trace(rate: f64, duration: f64, seed: u64) -> Trace {
        Trace::generate(&TraceConfig {
            kind: WorkloadKind::CodeFuse,
            rate,
            duration,
            max_input_len: 512,
            max_gen_len: 512,
            seed,
        })
    }

    fn cfg() -> SimConfig {
        SimConfig::new(4, EnginePreset::paper(EngineKind::Ds), 512, 7)
    }

    fn stamped_trace(rate: f64, duration: f64, seed: u64, slo: &str) -> Trace {
        let mut t = small_trace(rate, duration, seed);
        let mix = TenantMix::parse("2:3,1").unwrap();
        let base = SloSpec::parse(slo).unwrap();
        stamp_trace(&mut t, &mix, &base, seed);
        t
    }

    #[test]
    fn d_scls_conserves_requests_and_tracks_every_slo() {
        let trace = stamped_trace(4.0, 30.0, 1, "ttft:5,deadline:60");
        let c = cfg();
        let spec = SchedulerSpec::d_scls(&c.engine, 64);
        let mut p = DeadlineSclsPolicy::new(&spec, &c);
        let m = run_policy(&trace, &mut p, c.workers, &mut NullSink);
        // Every request either completes or is shed — none lost.
        assert_eq!(
            m.completed.len() as u64 + m.shed_requests,
            trace.len() as u64
        );
        // Every stamped request carries an SLO, so all are tracked.
        assert_eq!(m.slo.tracked, trace.len() as u64);
        assert_eq!(m.slo.shed, m.shed_requests);
    }

    #[test]
    fn d_scls_sheds_infeasible_deadlines() {
        // Millisecond deadlines no pass can meet: D-SCLS must shed rather
        // than serve guaranteed misses.
        let trace = stamped_trace(4.0, 20.0, 2, "deadline:0.001");
        let c = cfg();
        let spec = SchedulerSpec::d_scls(&c.engine, 64);
        let mut p = DeadlineSclsPolicy::new(&spec, &c);
        let m = run_policy(&trace, &mut p, c.workers, &mut NullSink);
        assert!(m.shed_requests > 0, "nothing shed under 1ms deadlines");
        assert_eq!(
            m.completed.len() as u64 + m.shed_requests,
            trace.len() as u64
        );
        assert!(m.slo.deadline_misses >= m.slo.shed);
    }

    #[test]
    fn d_scls_generous_deadlines_complete_everything() {
        let trace = stamped_trace(3.0, 20.0, 3, "deadline:100000");
        let c = cfg();
        let spec = SchedulerSpec::d_scls(&c.engine, 64);
        let mut p = DeadlineSclsPolicy::new(&spec, &c);
        let m = run_policy(&trace, &mut p, c.workers, &mut NullSink);
        assert_eq!(m.completed.len(), trace.len());
        assert_eq!(m.shed_requests, 0);
        assert_eq!(m.slo.tracked, trace.len() as u64);
    }

    #[test]
    fn ranked_policies_complete_all_requests() {
        let trace = small_trace(4.0, 30.0, 4);
        let c = cfg();
        let mut srpt = RankedSlicePolicy::new(
            &SchedulerSpec::p_srpt(&c.engine, 64),
            &c,
            RankKey::PredictedRemaining,
            Some(c.predictor.build(c.max_gen_len, c.seed)),
        );
        let m = run_policy(&trace, &mut srpt, c.workers, &mut NullSink);
        assert_eq!(m.completed.len(), trace.len());
        assert_eq!(m.shed_requests, 0, "P-SRPT never sheds");
        let mut sw = RankedSlicePolicy::new(
            &SchedulerSpec::sw_slo(&c.engine, 64),
            &c,
            RankKey::DeadlineSlack,
            None,
        );
        let m = run_policy(&trace, &mut sw, c.workers, &mut NullSink);
        assert_eq!(m.completed.len(), trace.len(), "the window only throttles");
        assert_eq!(m.shed_requests, 0, "SW-SLO never sheds");
    }

    #[test]
    fn slo_policies_are_deterministic() {
        let trace = stamped_trace(4.0, 20.0, 5, "ttft:3,deadline:45");
        let c = cfg();
        let spec = SchedulerSpec::d_scls(&c.engine, 64);
        let run = || {
            let mut p = DeadlineSclsPolicy::new(&spec, &c);
            run_policy(&trace, &mut p, c.workers, &mut NullSink)
                .to_json()
                .to_string_pretty()
        };
        assert_eq!(run(), run());
        let run_sw = || {
            let mut p = RankedSlicePolicy::new(
                &SchedulerSpec::sw_slo(&c.engine, 64),
                &c,
                RankKey::DeadlineSlack,
                None,
            );
            run_policy(&trace, &mut p, c.workers, &mut NullSink)
                .to_json()
                .to_string_pretty()
        };
        assert_eq!(run_sw(), run_sw());
    }

    #[test]
    fn static_policies_stamp_first_token_times() {
        let trace = stamped_trace(3.0, 20.0, 6, "ttft:5,deadline:120");
        let c = cfg();
        let spec = SchedulerSpec::d_scls(&c.engine, 64);
        let mut p = DeadlineSclsPolicy::new(&spec, &c);
        let m = run_policy(&trace, &mut p, c.workers, &mut NullSink);
        // Every completion folded a TTFT sample into the streaming sketch
        // (sheds never do), and the sketched p99 is a real measurement.
        assert_eq!(m.slo.ttft_hist.count() as usize, m.completed.len());
        assert_eq!(m.slo.tpot_hist.count() as usize, m.completed.len());
        assert!(m.slo.ttft_p99() > 0.0);
    }
}
