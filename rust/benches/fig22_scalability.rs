//! Fig. 22 — scalability: SCLS throughput vs number of workers (1–8) for
//! both engines; the paper reports linear scaling. Prints the reproduced
//! series and checks linearity, then times the DES as cluster size grows
//! (the simulator itself must scale too).

use scls::bench::figures::{fig22, run_cell, FigureConfig};
use scls::bench::harness::{bench, report_header};
use scls::engine::presets::EngineKind;

fn main() {
    let fc = FigureConfig::quick(0.1);
    let r = fig22(&fc, &[1, 2, 4, 8]);
    r.print();

    // Linearity check on the printed series (DS rows).
    let ds: Vec<(f64, f64)> = r
        .rows
        .iter()
        .filter(|row| row[0] == "DS")
        .map(|row| (row[1].parse().unwrap(), row[2].parse().unwrap()))
        .collect();
    if let (Some(first), Some(last)) = (ds.first(), ds.last()) {
        let speedup = last.1 / first.1;
        let ideal = last.0 / first.0;
        println!(
            "DS speedup {}→{} workers: {speedup:.2}× (ideal {ideal:.0}×, {:.0}% efficiency)\n",
            first.0 as u32,
            last.0 as u32,
            100.0 * speedup / ideal
        );
    }

    println!("{}", report_header());
    let small = FigureConfig::quick(0.05);
    for w in [1usize, 4, 8] {
        let fcw = FigureConfig {
            workers: w,
            ..small.clone()
        };
        let r = bench(&format!("SCLS DS, {w} workers (30 s trace)"), || {
            run_cell(&fcw, EngineKind::Ds, "SCLS", 20.0, fcw.slice_len)
        });
        println!("{}", r.report());
    }
}
