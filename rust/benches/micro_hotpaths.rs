//! Micro-benchmarks of the coordinator hot paths (`cargo bench`):
//! the DP batcher (Alg. 1) against its retained quadratic reference, the
//! O(1) serving-time estimate, the max-min offloader, the DES engine
//! slice, the event queue, and — when artifacts are present — one real
//! PJRT slice execution.
//!
//! These are the paths on the schedule tick: at rate 20 with Γ≈3 s a tick
//! batches ~60 requests, and at the scale benchmark's rates a tick batches
//! hundreds of thousands; everything here must be far below the tick
//! interval.
//!
//! The DP rows time the *planner alone* over a pre-sorted pool: the former
//! version cloned the request vector inside the timed closure, so the
//! clone was measured as part of the batcher's number. Both the optimized
//! and the quadratic-reference rows see the identical pre-sorted input,
//! making the printed speedup an apples-to-apples algorithmic comparison.

use scls::batcher::{
    dp_plan, dp_plan_corrected_reference, dp_plan_reference, DpBatcherConfig, DpScratch,
};
use scls::bench::harness::{bench, report_header};
use scls::core::{Batch, Request};
use scls::engine::presets::{EngineKind, EnginePreset};
use scls::engine::sim::SimEngine;
use scls::estimator::serving_time::ServeEstimate;
use scls::offloader::{LoadLedger, MaxMinOffloader};
use scls::sim::driver::{fitted_estimator, SimConfig, Simulation};
use scls::sim::EventQueue;
use scls::telemetry::profile;
use scls::util::rng::Rng;
use scls::workload::distributions::WorkloadKind;
use scls::workload::{Trace, TraceConfig};

fn requests(n: usize, seed: u64) -> Vec<Request> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|i| {
            let li = 1 + (rng.next_u64() % 1024) as u32;
            let gl = 1 + (rng.next_u64() % 1024) as u32;
            Request::new(i as u64, 0.0, li, gl)
        })
        .collect()
}

fn sorted_requests(n: usize, seed: u64) -> Vec<Request> {
    let mut reqs = requests(n, seed);
    reqs.sort_by_key(|r| r.input_len);
    reqs
}

/// Sorted pool with oracle-stamped predictions (predicted == target
/// generation) — the shape the prediction-corrected planner sees under
/// P-SCLS with the oracle predictor. Same pool and sort discipline as the
/// legacy rows (stamping is per-request, so it cannot perturb the sort).
fn sorted_predicted_requests(n: usize, seed: u64) -> Vec<Request> {
    let mut reqs = sorted_requests(n, seed);
    for r in &mut reqs {
        r.predicted_gen = Some(r.target_gen_len);
    }
    reqs
}

fn main() {
    let preset = EnginePreset::paper(EngineKind::Ds);
    let est = fitted_estimator(&preset, 7);
    let mem = preset.memory_estimator();
    let cfg = DpBatcherConfig {
        slice_len: 128,
        max_batch_size: None,
        pred_corrected: false,
    };

    println!("{}", report_header());

    // Serving-time estimate: called O(n·N_max) per reference DP run.
    let r = bench("estimator::serve(12, 512, 128)", || {
        est.serve_est(12, 512, 128)
    });
    println!("{}", r.report());

    // DP batcher at the per-tick scales the paper's rates produce, on both
    // memory rules (DS: Alg. 2 table, windows ≤ 28; HF: analytic Eq. 8,
    // windows of hundreds). Planner-only timing — no clone, no batch
    // materialization — optimized vs the retained quadratic reference.
    for (rule_name, rule_preset) in [("ds", EngineKind::Ds), ("hf", EngineKind::Hf)] {
        let rule_mem = EnginePreset::paper(rule_preset).memory_estimator();
        for &n in &[16usize, 64, 256, 1024] {
            let reqs = sorted_requests(n, 42);
            let mut scratch = DpScratch::new();
            let fast = bench(&format!("dp_batch({n} requests, {rule_name} rule)"), || {
                dp_plan(&reqs, &est, &rule_mem, &cfg, &mut scratch);
                scratch.cuts().len()
            });
            println!("{}", fast.report());
            let slow = bench(
                &format!("dp_batch_quadratic({n} requests, {rule_name} rule)"),
                || dp_plan_reference(&reqs, &est, &rule_mem, &cfg).len(),
            );
            println!("{}", slow.report());
            println!(
                "   -> dp_batch speedup vs quadratic ({rule_name}, n={n}): {:.2}x",
                slow.mean_ns / fast.mean_ns
            );
        }
    }

    // Prediction-corrected planner: the branch-and-bound (dp_plan with
    // pred_corrected) against the retained scalar reference, on oracle-
    // stamped pools. Same planner-only discipline: identical pre-sorted
    // input, no clone or materialization in the timed region.
    let corr_cfg = DpBatcherConfig {
        slice_len: 128,
        max_batch_size: None,
        pred_corrected: true,
    };
    for (rule_name, rule_preset) in [("ds", EngineKind::Ds), ("hf", EngineKind::Hf)] {
        let rule_mem = EnginePreset::paper(rule_preset).memory_estimator();
        for &n in &[16usize, 64, 256, 1024] {
            let reqs = sorted_predicted_requests(n, 42);
            let mut scratch = DpScratch::new();
            let fast = bench(&format!("dp_corrected_bnb({n} requests, {rule_name} rule)"), || {
                dp_plan(&reqs, &est, &rule_mem, &corr_cfg, &mut scratch);
                scratch.cuts().len()
            });
            println!("{}", fast.report());
            let slow = bench(
                &format!("dp_corrected_scalar({n} requests, {rule_name} rule)"),
                || dp_plan_corrected_reference(&reqs, &est, &rule_mem, &corr_cfg).len(),
            );
            println!("{}", slow.report());
            println!(
                "   -> dp_corrected speedup vs scalar ({rule_name}, n={n}): {:.2}x",
                slow.mean_ns / fast.mean_ns
            );
        }
    }

    // Max-min offloading of a tick's worth of batches onto 8 workers.
    {
        use scls::batcher::dp_batch;
        let batches: Vec<Batch> = dp_batch(requests(256, 1), &est, &mem, &cfg);
        let n_batches = batches.len();
        // Recycle the batches between iterations instead of cloning inside
        // the timed region (the clone skew this file's DP rows also fix).
        // After the first call the queue is already sorted, so this is the
        // steady-state cost of offloading a pre-sorted queue.
        let mut pool: Vec<Batch> = batches;
        let mut out: Vec<(usize, Batch)> = Vec::with_capacity(n_batches);
        let r = bench(&format!("maxmin_offload({n_batches} batches, 8 workers)"), || {
            pool.extend(out.drain(..).map(|(_, b)| b));
            let mut ledger = LoadLedger::new(8);
            MaxMinOffloader.offload_into(&mut pool, &mut ledger, &mut out);
            out.len()
        });
        println!("{}", r.report());
    }

    // One simulated slice serving (the per-event DES cost).
    {
        let mut engine = SimEngine::new(preset.latency(3), 1024);
        let batch = Batch::new(requests(12, 5));
        let r = bench("sim_engine::serve_slice(N=12, S=128)", || {
            engine.serve_slice(&batch, 128)
        });
        println!("{}", r.report());
    }

    // Event queue churn at DES scale.
    {
        let r = bench("event_queue push+pop x1000", || {
            let mut q: EventQueue<u32> = EventQueue::with_capacity(1000);
            for i in 0..1000u32 {
                q.push((i as f64 * 1.37) % 97.0, i);
            }
            let mut acc = 0u64;
            while let Some((_, v)) = q.pop() {
                acc += v as u64;
            }
            acc
        });
        println!("{}", r.report());
    }

    // Hot-path profile over a short end-to-end SCLS run: the same sections
    // `simulate --profile` reports (dp_plan, offload, drain_sort,
    // schedule_tick), measured in situ rather than in isolation — this is
    // where the per-tick shares show up.
    {
        let trace = Trace::generate(&TraceConfig {
            kind: WorkloadKind::CodeFuse,
            rate: 20.0,
            duration: 30.0,
            max_input_len: 1024,
            max_gen_len: 1024,
            seed: 42,
        });
        let sim =
            Simulation::new(SimConfig::new(8, EnginePreset::paper(EngineKind::Ds), 1024, 42));
        profile::enable();
        let m = sim.run_named(&trace, "SCLS", 128).expect("SCLS run");
        profile::disable();
        println!("scls end-to-end (30 s trace, rate 20): {} completed", m.completed.len());
        print!("{}", profile::take().report());
    }

    // Real PJRT slice execution, when artifacts exist (the L3→runtime hot
    // call; everything else in a real deployment hides behind this).
    let art = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if art.join("manifest.json").exists() {
        use scls::engine::real::RealEngine;
        let mut engine = RealEngine::new(&art, 16, 64).expect("load artifacts");
        engine.warmup().expect("warmup");
        for &(n, l) in &[(1usize, 8usize), (4, 24), (8, 56)] {
            let reqs: Vec<Request> = (0..n)
                .map(|i| {
                    Request::with_tokens(
                        i as u64,
                        0.0,
                        (0..l).map(|k| 3 + ((i * 31 + k) % 400) as i32).collect(),
                    )
                })
                .collect();
            let batch = Batch::new(reqs);
            let r = bench(&format!("pjrt_slice(N={n}, L_in={l}, S=16)"), || {
                engine.serve_slice(&batch).unwrap()
            });
            println!("{}", r.report());
        }
    } else {
        println!("(skipping PJRT benches: run `make artifacts` first)");
    }
}
