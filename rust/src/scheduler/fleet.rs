//! Worker-lifecycle bookkeeping for the elastic fleet.
//!
//! [`WorkerLedger`] is the coordinator-side source of truth for which
//! workers may be assigned work: per-worker health
//! ([`WorkerHealth::Alive`] / `Draining` / `Dead`), a last-heartbeat
//! clock, in-flight batch ownership, and the last slice boundary each
//! worker completed. A crash consults the ledger to know exactly how much
//! work was in flight (one slice at most — the SCLS structural gift: every
//! slice boundary is a checkpoint), and the stale-work reclaim path
//! re-queues survivors from that boundary.

/// Lifecycle state of one worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkerHealth {
    /// Accepting and serving work.
    Alive,
    /// Finishing in-flight work; accepts nothing new. Transitions to
    /// [`WorkerHealth::Dead`] once its queues empty.
    Draining,
    /// Gone: crashed, or a drain that finished. Never assigned work again
    /// (worker indices are not reused; joiners get fresh indices).
    Dead,
}

/// One worker's authoritative self-report, used to reconstruct a crashed
/// coordinator's ledger. Workers own the ground truth the coordinator
/// merely mirrors: their health, the batch they are serving, the last
/// slice boundary they completed, and the serving-plus-queued load they
/// still owe (which equals the pre-crash [`crate::offloader::LoadLedger`]
/// entry exactly, since the ledger charges per assignment and releases per
/// batch completion — both replayable from worker-side state).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WorkerReport {
    pub worker: usize,
    pub health: WorkerHealth,
    /// Requests in the batch currently serving (0 when idle).
    pub in_flight: usize,
    /// Slice boundaries completed over the worker's lifetime.
    pub progress: u64,
    /// Estimated serve time of the serving slot plus every queued batch.
    pub charged_load: f64,
}

/// Per-worker lifecycle ledger: health, heartbeats, in-flight ownership,
/// last completed slice boundary.
#[derive(Debug, Clone, Default)]
pub struct WorkerLedger {
    health: Vec<WorkerHealth>,
    last_heartbeat: Vec<f64>,
    in_flight: Vec<usize>,
    last_progress_slice: Vec<u64>,
}

impl WorkerLedger {
    pub fn new(workers: usize) -> Self {
        WorkerLedger {
            health: vec![WorkerHealth::Alive; workers],
            last_heartbeat: vec![0.0; workers],
            in_flight: vec![0; workers],
            last_progress_slice: vec![0; workers],
        }
    }

    /// Rebuild a ledger from worker self-reports after a coordinator
    /// crash. Reports must be index-dense (report `i` describes worker
    /// `i`); heartbeats restart at `now` — the successor has no memory of
    /// older beats, and every reporting worker just proved liveness.
    pub fn from_reports(now: f64, reports: &[WorkerReport]) -> Self {
        let mut l = WorkerLedger::new(reports.len());
        for (i, r) in reports.iter().enumerate() {
            debug_assert_eq!(i, r.worker, "reports must be index-dense");
            l.health[i] = r.health;
            l.last_heartbeat[i] = now;
            l.in_flight[i] = r.in_flight;
            l.last_progress_slice[i] = r.progress;
        }
        l
    }

    /// Register a cold joiner; returns its (fresh, never-reused) index.
    pub fn add_worker(&mut self, now: f64) -> usize {
        self.health.push(WorkerHealth::Alive);
        self.last_heartbeat.push(now);
        self.in_flight.push(0);
        self.last_progress_slice.push(0);
        self.health.len() - 1
    }

    /// Total workers ever registered (alive or not).
    pub fn workers(&self) -> usize {
        self.health.len()
    }

    pub fn health(&self, w: usize) -> WorkerHealth {
        self.health[w]
    }

    pub fn set_health(&mut self, w: usize, h: WorkerHealth) {
        self.health[w] = h;
    }

    /// May this worker be handed *new* work? (Only `Alive` accepts;
    /// draining workers finish what they hold.)
    pub fn accepts(&self, w: usize) -> bool {
        self.health[w] == WorkerHealth::Alive
    }

    pub fn heartbeat(&mut self, w: usize, now: f64) {
        self.last_heartbeat[w] = now;
    }

    pub fn last_heartbeat(&self, w: usize) -> f64 {
        self.last_heartbeat[w]
    }

    /// A batch of `size` requests started serving on `w`.
    pub fn batch_started(&mut self, w: usize, size: usize, now: f64) {
        self.in_flight[w] = size;
        self.last_heartbeat[w] = now;
    }

    /// The in-flight batch on `w` reached its slice boundary: ownership
    /// clears, the progress cursor advances, the heartbeat refreshes.
    pub fn batch_completed(&mut self, w: usize, now: f64) {
        self.in_flight[w] = 0;
        self.last_progress_slice[w] += 1;
        self.last_heartbeat[w] = now;
    }

    /// Requests currently owned by an in-flight batch on `w` (0 when idle).
    pub fn in_flight(&self, w: usize) -> usize {
        self.in_flight[w]
    }

    /// Slice boundaries `w` has completed over its lifetime.
    pub fn last_progress(&self, w: usize) -> u64 {
        self.last_progress_slice[w]
    }

    /// Forget in-flight ownership without crediting progress — the crash
    /// path: the slice being served is lost.
    pub fn clear_in_flight(&mut self, w: usize) {
        self.in_flight[w] = 0;
    }

    pub fn accepting_count(&self) -> usize {
        self.health.iter().filter(|h| **h == WorkerHealth::Alive).count()
    }

    pub fn alive_count(&self) -> usize {
        self.health
            .iter()
            .filter(|h| **h != WorkerHealth::Dead)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_fleet_all_accepting() {
        let l = WorkerLedger::new(3);
        assert_eq!(l.workers(), 3);
        assert_eq!(l.accepting_count(), 3);
        assert!((0..3).all(|w| l.accepts(w)));
    }

    #[test]
    fn joiner_gets_fresh_index() {
        let mut l = WorkerLedger::new(2);
        l.set_health(1, WorkerHealth::Dead);
        let w = l.add_worker(5.0);
        assert_eq!(w, 2); // dead index 1 is never reused
        assert!(l.accepts(2));
        assert_eq!(l.last_heartbeat(2), 5.0);
        assert_eq!(l.accepting_count(), 2);
    }

    #[test]
    fn draining_holds_work_but_accepts_nothing() {
        let mut l = WorkerLedger::new(2);
        l.batch_started(0, 4, 1.0);
        l.set_health(0, WorkerHealth::Draining);
        assert!(!l.accepts(0));
        assert_eq!(l.in_flight(0), 4);
        assert_eq!(l.alive_count(), 2);
        assert_eq!(l.accepting_count(), 1);
    }

    #[test]
    fn progress_cursor_advances_per_slice_boundary() {
        let mut l = WorkerLedger::new(1);
        l.batch_started(0, 3, 1.0);
        l.batch_completed(0, 2.0);
        assert_eq!(l.in_flight(0), 0);
        assert_eq!(l.last_progress(0), 1);
        assert_eq!(l.last_heartbeat(0), 2.0);
    }

    #[test]
    fn rebuild_from_reports_restores_worker_truth() {
        let reports = [
            WorkerReport {
                worker: 0,
                health: WorkerHealth::Alive,
                in_flight: 3,
                progress: 7,
                charged_load: 1.5,
            },
            WorkerReport {
                worker: 1,
                health: WorkerHealth::Dead,
                in_flight: 0,
                progress: 2,
                charged_load: 0.0,
            },
            WorkerReport {
                worker: 2,
                health: WorkerHealth::Draining,
                in_flight: 1,
                progress: 4,
                charged_load: 0.25,
            },
        ];
        let l = WorkerLedger::from_reports(9.0, &reports);
        assert_eq!(l.workers(), 3);
        assert_eq!(l.health(0), WorkerHealth::Alive);
        assert_eq!(l.health(1), WorkerHealth::Dead);
        assert_eq!(l.health(2), WorkerHealth::Draining);
        assert_eq!(l.in_flight(0), 3);
        assert_eq!(l.last_progress(0), 7);
        assert_eq!(l.last_progress(2), 4);
        assert_eq!(l.accepting_count(), 1);
        assert!((0..3).all(|w| l.last_heartbeat(w) == 9.0));
    }

    #[test]
    fn crash_clears_ownership_without_progress() {
        let mut l = WorkerLedger::new(1);
        l.batch_started(0, 3, 1.0);
        l.clear_in_flight(0);
        l.set_health(0, WorkerHealth::Dead);
        assert_eq!(l.in_flight(0), 0);
        assert_eq!(l.last_progress(0), 0);
        assert_eq!(l.accepting_count(), 0);
        assert_eq!(l.alive_count(), 0);
    }
}
