//! Max-min offloading (paper §4.5): offload batches one by one, longest
//! estimated serving time first, each to the currently least-loaded worker
//! — the classic LPT (longest processing time) list-scheduling rule, which
//! guarantees a makespan within 4/3 of optimal.

use crate::core::Batch;

use super::LoadLedger;

#[derive(Debug, Default)]
pub struct MaxMinOffloader;

impl MaxMinOffloader {
    /// Assign each batch a worker; returns (worker, batch) pairs in the
    /// order they were assigned (longest first). Updates the ledger.
    pub fn offload(&self, mut batches: Vec<Batch>, ledger: &mut LoadLedger) -> Vec<(usize, Batch)> {
        let mut out = Vec::with_capacity(batches.len());
        self.offload_into(&mut batches, ledger, &mut out);
        out
    }

    /// Allocation-lean variant for per-tick callers: drains `batches`
    /// (keeping its capacity) and pushes assignments into `out` (cleared
    /// first). Identical policy and ordering to [`Self::offload`].
    ///
    /// Only **accepting** workers are targeted (the ledger's mask — dead
    /// or draining workers never receive work). If no worker accepts —
    /// mid-fault, or an empty ledger — the batches are left in `batches`
    /// for the caller to re-pool rather than assigned to a ghost index.
    pub fn offload_into(
        &self,
        batches: &mut Vec<Batch>,
        ledger: &mut LoadLedger,
        out: &mut Vec<(usize, Batch)>,
    ) {
        // Opt-in hot-path profiling: one thread-local bool load when
        // disabled.
        let _t = crate::telemetry::profile::timer("offload"); // scls-lint: allow(import-graph): opt-in profiling tap
        out.clear();
        // Longest estimated serving time first.
        batches.sort_by(|a, b| b.est_serve_time.total_cmp(&a.est_serve_time));
        if ledger.try_argmin().is_none() {
            return; // nowhere to place work; leave batches with the caller
        }
        out.reserve(batches.len());
        for b in batches.drain(..) {
            let w = ledger.argmin();
            ledger.add(w, b.est_serve_time);
            out.push((w, b));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::core::Request;

    fn batch(id_base: u64, est: f64) -> Batch {
        let mut b = Batch::new(vec![Request::new(id_base, 0.0, 10, 10)]);
        b.est_serve_time = est;
        b
    }

    #[test]
    fn longest_goes_to_least_loaded() {
        let mut ledger = LoadLedger::new(2);
        ledger.add(0, 5.0);
        let out = MaxMinOffloader.offload(vec![batch(1, 9.0), batch(2, 1.0)], &mut ledger);
        // 9.0 -> worker 1 (load 0), then 1.0 -> worker 1? loads: w0=5, w1=9
        assert_eq!(out[0].0, 1);
        assert_eq!(out[1].0, 0);
    }

    #[test]
    fn balances_better_than_naive_order() {
        // Classic LPT adversary: jobs 5,4,3,3,3 on 2 workers. Optimal
        // makespan is 9 (5+4 | 3+3+3); LPT gives 10 — within its 4/3·OPT
        // guarantee — while arrival-order list scheduling gives 10 as well
        // on this instance, and LPT can never be worse.
        let jobs = [3.0, 3.0, 5.0, 4.0, 3.0];
        let mut ledger = LoadLedger::new(2);
        let batches = jobs.iter().enumerate().map(|(i, &t)| batch(i as u64, t)).collect();
        MaxMinOffloader.offload(batches, &mut ledger);
        let lpt_makespan = ledger.max();
        assert!(lpt_makespan <= 4.0 / 3.0 * 9.0 + 1e-9, "{lpt_makespan}");

        // Arrival-order (no sort) list scheduling for comparison.
        let mut naive = LoadLedger::new(2);
        for &t in &jobs {
            let w = naive.argmin();
            naive.add(w, t);
        }
        assert!(
            lpt_makespan <= naive.max() + 1e-9,
            "LPT {lpt_makespan} worse than naive {}",
            naive.max()
        );

        // An instance where LPT balances exactly: 4,3,3,2,2,2 → 8 | 8.
        let mut ledger = LoadLedger::new(2);
        let batches = [2.0, 4.0, 2.0, 3.0, 3.0, 2.0]
            .iter()
            .enumerate()
            .map(|(i, &t)| batch(i as u64, t))
            .collect();
        MaxMinOffloader.offload(batches, &mut ledger);
        assert!((ledger.max() - ledger.min()).abs() <= 1e-9);
    }

    #[test]
    fn single_worker_takes_all() {
        let mut ledger = LoadLedger::new(1);
        let out = MaxMinOffloader.offload(vec![batch(1, 2.0), batch(2, 3.0)], &mut ledger);
        assert!(out.iter().all(|(w, _)| *w == 0));
        assert_eq!(ledger.load(0), 5.0);
    }

    #[test]
    fn empty_batches() {
        let mut ledger = LoadLedger::new(4);
        assert!(MaxMinOffloader.offload(vec![], &mut ledger).is_empty());
    }

    #[test]
    fn all_but_one_dead_routes_everything_to_the_survivor() {
        let mut ledger = LoadLedger::new(4);
        for w in [0, 1, 3] {
            ledger.set_accepting(w, false);
        }
        let mut batches = vec![batch(1, 9.0), batch(2, 1.0), batch(3, 4.0)];
        let mut out = Vec::new();
        MaxMinOffloader.offload_into(&mut batches, &mut ledger, &mut out);
        assert!(batches.is_empty());
        assert_eq!(out.len(), 3);
        assert!(out.iter().all(|(w, _)| *w == 2), "{out:?}");
        assert_eq!(ledger.load(2), 14.0);
        assert_eq!(ledger.load(0), 0.0);
    }

    #[test]
    fn no_accepting_worker_leaves_batches_with_caller() {
        // Whole fleet masked out mid-fault …
        let mut ledger = LoadLedger::new(2);
        ledger.set_accepting(0, false);
        ledger.set_accepting(1, false);
        let mut batches = vec![batch(1, 2.0)];
        let mut out = Vec::new();
        MaxMinOffloader.offload_into(&mut batches, &mut ledger, &mut out);
        assert_eq!(batches.len(), 1, "unplaceable batches must stay with the caller");
        assert!(out.is_empty());

        // … and the degenerate empty ledger (would previously have indexed
        // out of bounds via argmin()==0).
        let mut empty = LoadLedger::new(0);
        let mut batches = vec![batch(2, 3.0)];
        MaxMinOffloader.offload_into(&mut batches, &mut empty, &mut out);
        assert_eq!(batches.len(), 1);
        assert!(out.is_empty());
    }
}
