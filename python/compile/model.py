"""L2 — tiny-GPT decoder-only model with static-batching slice generation.

This is the *compute substrate* for the real-engine path of the SCLS
reproduction: a deterministic, randomly-initialized decoder-only transformer
small enough that CPU PJRT can serve it interactively, but implementing the
exact static-batching semantics the paper's engines (huggingface-transformers /
deepspeed-inference) expose to the scheduler (§2.4):

* batches are **left-padded** to a common length ``L``;
* pad tokens are masked out of attention;
* generation runs for **exactly ``S`` iterations** (the slice length) unless
  *every* active row has emitted EOS earlier — the paper's "early return";
* rows that emit EOS early keep generating **invalid tokens** until the slice
  ends (they still burn compute — that is the inefficiency SCLS exploits).

The whole prefill + S-step decode loop is a single jittable function so that
``aot.py`` can lower one self-contained HLO program per (N, L, S) bucket;
Rust then makes exactly one PJRT call per batch per slice.

Weights are generated from a fixed seed at export time and baked into the HLO
as constants — the artifact is self-contained. A small position-progressive
EOS logit boost (``eos_alpha``) makes the random-init model emit EOS at
varied, content-dependent generation lengths, so the real engine exhibits the
length dispersion the paper's motivation (§3.3) relies on.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict

import jax
import jax.numpy as jnp

from .kernels import attention as K
from .kernels import ref as KREF

PAD_ID = 0
EOS_ID = 1
BOS_ID = 2


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture of the tiny-GPT demo model (baked into artifacts)."""

    vocab: int = 512
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 2
    max_pos: int = 256          # positional-embedding table size (>= L + S)
    mlp_ratio: int = 4
    eos_alpha: float = 0.35     # EOS logit boost per generated position
    param_seed: int = 20240612

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def kv_bytes_per_token(self) -> int:
        """Per-token KV-cache footprint (f32 K+V across layers) — the Δ of
        the paper's Eq. (5) for this model."""
        return self.n_layers * 2 * self.d_model * 4


def init_params(cfg: ModelConfig) -> Dict[str, Any]:
    """Deterministic random init (fixed seed ⇒ identical artifacts)."""
    key = jax.random.PRNGKey(cfg.param_seed)
    ks = iter(jax.random.split(key, 4 + 8 * cfg.n_layers))
    std = 0.08
    p: Dict[str, Any] = {
        "tok_emb": jax.random.normal(next(ks), (cfg.vocab, cfg.d_model)) * std,
        "pos_emb": jax.random.normal(next(ks), (cfg.max_pos, cfg.d_model)) * std,
        "lm_head": jax.random.normal(next(ks), (cfg.d_model, cfg.vocab)) * std,
        "ln_f": jnp.ones((cfg.d_model,)),
        "layers": [],
    }
    for _ in range(cfg.n_layers):
        layer = {
            "ln1": jnp.ones((cfg.d_model,)),
            "wqkv": jax.random.normal(next(ks), (cfg.d_model, 3 * cfg.d_model)) * std,
            "wo": jax.random.normal(next(ks), (cfg.d_model, cfg.d_model)) * std,
            "ln2": jnp.ones((cfg.d_model,)),
            "w1": jax.random.normal(next(ks), (cfg.d_model, cfg.mlp_ratio * cfg.d_model)) * std,
            "w2": jax.random.normal(next(ks), (cfg.mlp_ratio * cfg.d_model, cfg.d_model)) * std,
        }
        p["layers"].append(layer)
    return p


def _rmsnorm(x, scale):
    return x * scale * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _split_heads(x, n_heads, d_head):
    # (N, L, D) -> (N, H, L, dh)
    n, l, _ = x.shape
    return x.reshape(n, l, n_heads, d_head).transpose(0, 2, 1, 3)


def _merge_heads(x):
    # (N, H, L, dh) -> (N, L, D)
    n, h, l, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(n, l, h * dh)


def _logits(cfg: ModelConfig, params, h_last, gen_pos):
    """LM-head logits for the last position, with the EOS progression boost.

    ``gen_pos``: (N,) int32 — number of tokens each row has generated so far
    (0 at the prefill step). The boost grows linearly so every sequence
    terminates at a content-dependent, bounded length.
    """
    logits = h_last @ params["lm_head"]  # (N, V)
    boost = cfg.eos_alpha * gen_pos.astype(jnp.float32)
    logits = logits.at[:, EOS_ID].add(boost)
    # Never emit PAD/BOS: keeps the token stream clean for the runtime.
    logits = logits.at[:, PAD_ID].add(-1e9)
    logits = logits.at[:, BOS_ID].add(-1e9)
    return logits


def _block_prefill(cfg, layer, h, lengths, *, interpret, use_pallas):
    """One transformer block over the full padded batch; returns (h, k, v)."""
    x = _rmsnorm(h, layer["ln1"])
    qkv = x @ layer["wqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    qh = _split_heads(q, cfg.n_heads, cfg.d_head)
    kh = _split_heads(k, cfg.n_heads, cfg.d_head)
    vh = _split_heads(v, cfg.n_heads, cfg.d_head)
    if use_pallas:
        attn = K.prefill_attention(qh, kh, vh, lengths, interpret=interpret)
    else:
        attn = KREF.prefill_attention_ref(qh, kh, vh, lengths)
    h = h + _merge_heads(attn) @ layer["wo"]
    x = _rmsnorm(h, layer["ln2"])
    h = h + jax.nn.gelu(x @ layer["w1"]) @ layer["w2"]
    return h, kh, vh


def _block_decode(cfg, layer, h, k_cache, v_cache, starts, cur, *, interpret, use_pallas):
    """One transformer block for a single new token; returns (h, kc, vc)."""
    x = _rmsnorm(h, layer["ln1"])  # (N, 1, D)
    qkv = x @ layer["wqkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    qh = _split_heads(q, cfg.n_heads, cfg.d_head)  # (N, H, 1, dh)
    kh = _split_heads(k, cfg.n_heads, cfg.d_head)
    vh = _split_heads(v, cfg.n_heads, cfg.d_head)
    # Insert the new K/V at cache position cur - 1 (it must be attendable by
    # the current query: the valid window is [start, cur)).
    k_cache = jax.lax.dynamic_update_slice(k_cache, kh, (0, 0, cur - 1, 0))
    v_cache = jax.lax.dynamic_update_slice(v_cache, vh, (0, 0, cur - 1, 0))
    if use_pallas:
        attn = K.decode_attention(qh, k_cache, v_cache, starts, cur, interpret=interpret)
    else:
        attn = KREF.decode_attention_ref(qh, k_cache, v_cache, starts, cur)
    h = h + _merge_heads(attn) @ layer["wo"]
    x = _rmsnorm(h, layer["ln2"])
    h = h + jax.nn.gelu(x @ layer["w1"]) @ layer["w2"]
    return h, k_cache, v_cache


def prefill_and_generate(
    params,
    tokens,        # (N, L) int32, LEFT-padded with PAD_ID
    lengths,       # (N,)  int32, true lengths (1 <= len <= L for active rows)
    active,        # (N,)  int32, 1 = real request, 0 = filler row
    gen_offset=None,  # (N,) int32, tokens generated in previous slices
    *,
    cfg: ModelConfig,
    slice_len: int,
    interpret: bool = True,
    use_pallas: bool = True,
):
    """Serve one slice: prefill the padded batch, then decode ``slice_len``
    tokens (early-exiting iff every active row has emitted EOS).

    Returns ``(gen, iters)``:
      gen:   (N, slice_len) int32 — generated tokens; positions past the
             executed iteration count are PAD_ID.
      iters: ()  int32 — number of decode iterations actually executed
             (== slice_len unless the batch early-returned, §4.2).
    """
    n, l = tokens.shape
    s = slice_len
    cap = l + s  # KV-cache capacity for this bucket
    assert cap <= cfg.max_pos, "bucket exceeds positional table"
    if gen_offset is None:
        gen_offset = jnp.zeros((n,), jnp.int32)

    starts = (l - lengths).astype(jnp.int32)          # (N,)
    active_b = active.astype(jnp.bool_)

    # ---- prefill over the padded batch --------------------------------
    # Content position of column j in row i is j - starts[i] (clamped; the
    # attention mask makes pad-region outputs unread).
    cols = jnp.arange(l, dtype=jnp.int32)[None, :]
    pos = jnp.clip(cols - starts[:, None], 0, cfg.max_pos - 1)
    h = params["tok_emb"][tokens] + params["pos_emb"][pos]

    k_list, v_list = [], []
    for layer in params["layers"]:
        h, kh, vh = _block_prefill(
            cfg, layer, h, lengths, interpret=interpret, use_pallas=use_pallas
        )
        pad_kv = jnp.zeros((n, cfg.n_heads, s, cfg.d_head), jnp.float32)
        k_list.append(jnp.concatenate([kh, pad_kv], axis=2))  # (N,H,cap,dh)
        v_list.append(jnp.concatenate([vh, pad_kv], axis=2))
    k_caches = jnp.stack(k_list)  # (layers, N, H, cap, dh)
    v_caches = jnp.stack(v_list)

    h_last = _rmsnorm(h[:, -1, :], params["ln_f"])    # (N, D)
    logits = _logits(cfg, params, h_last, gen_offset)
    tok0 = jnp.argmax(logits, axis=-1).astype(jnp.int32)  # (N,)

    gen = jnp.full((n, s), PAD_ID, dtype=jnp.int32)
    gen = gen.at[:, 0].set(tok0)
    done = (tok0 == EOS_ID) | ~active_b

    # ---- decode loop with early return ---------------------------------
    def cond(state):
        t, _, _, _, _, done = state
        return (t < s) & ~jnp.all(done)

    def body(state):
        t, gen, prev, k_caches, v_caches, done = state
        # prev token sits at cache position l + t - 1; window is [start, cur).
        cur = l + t
        h = params["tok_emb"][prev][:, None, :] + params["pos_emb"][
            jnp.clip(lengths + t - 1, 0, cfg.max_pos - 1)
        ][:, None, :]
        new_k, new_v = [], []
        for li, layer in enumerate(params["layers"]):
            h, kc, vc = _block_decode(
                cfg, layer, h, k_caches[li], v_caches[li], starts, cur,
                interpret=interpret, use_pallas=use_pallas,
            )
            new_k.append(kc)
            new_v.append(vc)
        k_caches = jnp.stack(new_k)
        v_caches = jnp.stack(new_v)
        h_last = _rmsnorm(h[:, 0, :], params["ln_f"])
        logits = _logits(cfg, params, h_last, gen_offset + t)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        gen = gen.at[:, t].set(tok)
        done = done | (tok == EOS_ID)
        return t + 1, gen, tok, k_caches, v_caches, done

    t, gen, _, _, _, _ = jax.lax.while_loop(
        cond, body, (jnp.int32(1), gen, tok0, k_caches, v_caches, done)
    )
    return gen, t


def generate_slice_fn(cfg: ModelConfig, n: int, l: int, s: int, *, use_pallas=True, interpret=True):
    """Build the jittable (tokens, lengths, active) -> (gen, iters) closure
    for one (N, L, S) bucket, with weights baked in as constants."""
    params = init_params(cfg)

    def fn(tokens, lengths, active, gen_offset):
        return prefill_and_generate(
            params, tokens, lengths, active, gen_offset,
            cfg=cfg, slice_len=s, interpret=interpret, use_pallas=use_pallas,
        )

    return fn


# ---------------------------------------------------------------------------
# Stateless reference generator (test oracle for the cached/pallas path)
# ---------------------------------------------------------------------------

def generate_ref(params, tokens, lengths, active, gen_offset=None, *,
                 cfg: ModelConfig, slice_len: int):
    """Naive stateless oracle: re-runs the full prefill forward pass for every
    generated token (no KV cache, no Pallas, no early return inside HLO) and
    applies the early-return rule in Python. Slow, but independently correct."""
    import numpy as np

    n, _ = tokens.shape
    if gen_offset is None:
        gen_offset = np.zeros((n,), np.int32)
    gen_offset = np.asarray(gen_offset)
    act = np.asarray(active).astype(bool)
    outs = np.full((n, slice_len), PAD_ID, dtype=np.int32)
    done = ~act
    iters = 0

    cur_tokens = np.asarray(tokens).copy()
    cur_lens = np.asarray(lengths).copy()
    for t in range(slice_len):
        if done.all():
            break
        iters += 1
        lcur = cur_tokens.shape[1]
        starts = (lcur - jnp.asarray(cur_lens)).astype(jnp.int32)
        cols = jnp.arange(lcur, dtype=jnp.int32)[None, :]
        pos = jnp.clip(cols - starts[:, None], 0, cfg.max_pos - 1)
        h = params["tok_emb"][jnp.asarray(cur_tokens)] + params["pos_emb"][pos]
        for layer in params["layers"]:
            h, _, _ = _block_prefill(
                cfg, layer, h, jnp.asarray(cur_lens), interpret=True, use_pallas=False
            )
        h_last = _rmsnorm(h[:, -1, :], params["ln_f"])
        logits = _logits(cfg, params, h_last, jnp.asarray(gen_offset + t, jnp.int32))
        tok = np.asarray(jnp.argmax(logits, axis=-1)).astype(np.int32)
        outs[:, t] = tok
        done = done | (tok == EOS_ID)
        # Append token (stateless: grow the sequence; rows stay left-padded).
        cur_tokens = np.concatenate([cur_tokens, tok[:, None]], axis=1)
        cur_lens = cur_lens + 1
    return outs, iters
