//! FCFS fixed-batch-size batching — the conventional SLS policy (§1, §5.1):
//! requests are grouped in arrival order into chunks of `batch_size`.

use crate::core::{Batch, Request};
use crate::estimator::serving_time::ServeEstimate;

/// Chunk requests in arrival order into fixed-size batches. The final
/// partial chunk is emitted too (workers don't wait to fill a batch once
/// they are idle). `est`/`slice_len` fill in `est_serve_time` so offloaders
/// can keep load ledgers even for the baseline.
pub fn fcfs_batches(
    requests: Vec<Request>,
    batch_size: u32,
    est: &dyn ServeEstimate,
    slice_len: u32,
) -> Vec<Batch> {
    assert!(batch_size > 0);
    let mut batches = Vec::new();
    let mut cur: Vec<Request> = Vec::with_capacity(batch_size as usize);
    for r in requests {
        cur.push(r);
        if cur.len() == batch_size as usize {
            batches.push(std::mem::take(&mut cur));
        }
    }
    if !cur.is_empty() {
        batches.push(cur);
    }
    batches
        .into_iter()
        .map(|reqs| {
            let mut b = Batch::new(reqs);
            b.est_serve_time = est.serve_est(b.size() as u32, b.input_len(), slice_len);
            b
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::serving_time::{LinearLatency, ServingTimeEstimator};

    fn est() -> ServingTimeEstimator {
        ServingTimeEstimator {
            prefill: LinearLatency {
                c1: 1e-4,
                c2: 0.0,
                c3: 0.0,
                c4: 0.0,
            },
            decode: LinearLatency {
                c1: 0.0,
                c2: 0.0,
                c3: 0.0,
                c4: 1e-3,
            },
        }
    }

    fn reqs(n: usize) -> Vec<Request> {
        (0..n)
            .map(|i| Request::new(i as u64, i as f64, 10 + i as u32, 100))
            .collect()
    }

    #[test]
    fn chunks_preserve_arrival_order() {
        let batches = fcfs_batches(reqs(10), 4, &est(), 128);
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].size(), 4);
        assert_eq!(batches[1].size(), 4);
        assert_eq!(batches[2].size(), 2);
        assert_eq!(batches[0].requests[0].id, 0);
        assert_eq!(batches[2].requests[1].id, 9);
    }

    #[test]
    fn exact_multiple() {
        let batches = fcfs_batches(reqs(8), 4, &est(), 128);
        assert_eq!(batches.len(), 2);
        assert!(batches.iter().all(|b| b.size() == 4));
    }

    #[test]
    fn empty() {
        assert!(fcfs_batches(vec![], 4, &est(), 128).is_empty());
    }

    #[test]
    fn est_filled() {
        let batches = fcfs_batches(reqs(3), 4, &est(), 128);
        assert!(batches[0].est_serve_time > 0.0);
    }
}
