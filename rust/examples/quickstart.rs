//! Quickstart: the five-minute tour of the SCLS library.
//!
//! Generates a CodeFuse-shaped request trace, runs it through the paper's
//! three contenders — SLS (sequence-level), ILS (iteration-level,
//! continuous batching) and SCLS (slice-level) — on the calibrated
//! discrete-event simulation of an 8×A100 LLaMA2-13B cluster, and prints
//! the comparison the paper's Fig. 5 makes.
//!
//! Run with: `cargo run --release --example quickstart`

use scls::engine::presets::{EngineKind, EnginePreset};
use scls::scheduler::spec::SchedulerSpec;
use scls::sim::driver::{run_ils, run_sliced, SimConfig};
use scls::workload::distributions::WorkloadKind;
use scls::workload::{Trace, TraceConfig};

fn main() {
    // 1. A workload: Poisson arrivals at 20 req/s for 2 minutes, with
    //    input/generation lengths shaped like the CodeFuse production trace
    //    (paper Fig. 6a: vast majority of generations < 512 tokens).
    let trace = Trace::generate(&TraceConfig {
        kind: WorkloadKind::CodeFuse,
        rate: 20.0,
        duration: 120.0,
        max_input_len: 1024,
        max_gen_len: 1024,
        seed: 42,
    });
    println!("trace: {} requests over {:.0} s\n", trace.len(), trace.duration);

    // 2. A cluster: 8 simulated workers with the DeepSpeed-Inference-like
    //    latency/memory profile (paper §5.1).
    let engine = EngineKind::Ds;
    let preset = EnginePreset::paper(engine);
    let sim = SimConfig::new(8, preset.clone(), 1024, 42);

    // 3. The three schedulers. SCLS splits the 1024-token generation limit
    //    into 128-token slices; SLS serves to the full limit in one static
    //    batch; ILS joins/exits requests per iteration under a conservative
    //    parallelism cap.
    let sls = run_sliced(&trace, &SchedulerSpec::sls(&preset, 1024), &sim).summarize();
    let ils = run_ils(&trace, &sim).summarize();
    let scls = run_sliced(&trace, &SchedulerSpec::scls(&preset, 128), &sim).summarize();

    println!(
        "{:<6} {:>12} {:>10} {:>10} {:>12} {:>10} {:>10}",
        "sched", "thpt req/s", "avg RT s", "p95 RT s", "batch size", "pads/req", "CT std s"
    );
    for (name, s) in [("SLS", &sls), ("ILS", &ils), ("SCLS", &scls)] {
        println!(
            "{:<6} {:>12.2} {:>10.1} {:>10.1} {:>12.1} {:>10.1} {:>10.2}",
            name,
            s.throughput,
            s.avg_response_time,
            s.p95_response_time,
            s.avg_batch_size,
            s.avg_pad_tokens,
            s.ct_std
        );
    }

    println!(
        "\nSCLS vs SLS: {:+.1}% throughput, {:.1}% lower avg response time",
        100.0 * (scls.throughput / sls.throughput - 1.0),
        100.0 * (1.0 - scls.avg_response_time / sls.avg_response_time),
    );
    println!(
        "SCLS vs ILS: {:+.1}% throughput",
        100.0 * (scls.throughput / ils.throughput - 1.0),
    );
    assert!(scls.throughput > sls.throughput, "SCLS should beat SLS");
}
