//! Core domain types: requests, batches, engine outcomes.

pub mod batch;
pub mod request;

pub use batch::{Batch, BatchOutcome, RequestOutcome};
pub use request::{Request, RequestId};
